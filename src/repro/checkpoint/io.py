"""Checkpointing: flatten any pytree of arrays to a single .npz + a JSON
treedef sidecar. Path-keyed so checkpoints survive code-level pytree
reorderings, and restorable onto ShapeDtypeStruct templates for sharded
restore (each host reads only what it needs in a real deployment)."""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np


def _paths(tree) -> dict:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for kp, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in kp)
        out[key] = leaf
    return out


def _base(path: str) -> str:
    """Strip a trailing .npz suffix only — a mid-string `.npz` (e.g. a run
    dir named `sweep.npz_v2/`) is part of the path, not the extension."""
    return path[:-len(".npz")] if path.endswith(".npz") else path


def save_pytree(path: str, tree: Any, step: int = 0):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _paths(tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    np.savez(_base(path) + ".npz", **arrays)
    meta = {"step": step, "keys": sorted(arrays),
            "shapes": {k: list(a.shape) for k, a in arrays.items()},
            "dtypes": {k: str(a.dtype) for k, a in arrays.items()}}
    with open(_base(path) + ".json", "w") as f:
        json.dump(meta, f, indent=1)


def load_pytree(path: str, template: Any) -> Any:
    """Restore onto `template` (same structure; leaves may be
    ShapeDtypeStruct or arrays)."""
    flat_t = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    with np.load(_base(path) + ".npz") as z:
        for kp, leaf in flat_t[0]:
            key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                           for k in kp)
            arr = z[key]
            want = tuple(leaf.shape)
            if tuple(arr.shape) != want:
                raise ValueError(
                    f"checkpoint leaf {key!r}: stored shape {arr.shape} "
                    f"does not match template shape {want}")
            leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(flat_t[1], leaves)
