"""Deterministic fleet simulation: virtual-time client clocks.

The paper targets cross-device fleets whose clients are slow, flaky and
never synchronized. `repro.sim` models that WITHOUT real wall-clock time:
a `ClockModel` is a pure function `(client_id, round_idx) -> commit delay`
(in rounds, bounded by `d_max`), consumed by both collaborative engines to
drive the asynchronous event-ordered relay (repro.relay.events) — and, via
`get_download_clock`, the download-lag snapshot reads from the relay
history ring (repro.relay.history).
"""
from repro.sim.clocks import (ClockModel, HomogeneousClock,  # noqa: F401
                              LognormalClock, PeriodicClock,
                              PeriodicSyncClock,
                              get_clock, get_download_clock)
from repro.sim.population import (FREE_SEAT, CohortTable,  # noqa: F401
                                  RoundView, StreamingPopulation,
                                  get_arrivals)
