"""Streaming populations: client arrival/departure as a first-class schedule.

The paper's fleet is not a fixed roster — users install the app, churn, and
come back. This module models that the same way `sim.clocks` models
lateness: deterministically, with no hidden RNG state, so the sequential
oracle and the vectorized engine independently derive the SAME cohort
timeline and stay equivalence-testable.

Population model. External client ids are drawn from an unbounded space
(an arrival counter, cycled modulo `population` so sweeps can dial the
distinct-id space from 10³ to 10⁶ and beyond). The engines never size
state by that space: a bounded `CohortTable` of A SEATS holds the
currently-admitted clients, and everything the engines allocate — client
params, masks, upload rows — is (A, ...), never (N_population, ...). Ring
slots are tagged with the EXTERNAL id, which is what keeps relay
bookkeeping (owner exclusion, shard hashing) correct across seat reuse.

Per round the table yields a `RoundView`:
  - departures: each active client leaves with probability `p_leave`. A
    departed client keeps its seat (and its ring slots stay live — its
    observations are still valid history) until the seat is reclaimed.
  - arrivals: Poisson(`rate`) new ids. An arrival takes a FREE seat first;
    otherwise it reclaims the least-recently-active DEPARTED seat (LRU),
    and the old owner's external id is reported in `evicted` — the engines
    then call `policy.evict_owners`, invalidating the evicted owner's ring
    slots. LRU never touches an ACTIVE seat: when every seat is active the
    arrival is dropped (counted in `dropped`) — admission control, not
    eviction of a live client. A cycled id that is already seated rejoins
    in place (departed -> active again) instead of taking a second seat.
  - participation: `k` of the active seats, uniformly without replacement
    (all of them when fewer than k are active). Participants refresh the
    seat's `last_active` round, which is the LRU key.

Determinism is recursive replay (the `AdaptiveParticipation` pattern):
`round(r)` replays rounds 0..r from the per-round seeded RNG stream
`default_rng([seed, 0x5EA7, r])`; views are cached, and two tables built
from the same spec agree bit-for-bit in either engine.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, NamedTuple, Optional, Union

import numpy as np

from repro.specs import parse_spec

# Empty-seat sentinel. Matches relay.base.EMPTY_OWNER so a free seat's id
# can never collide with a live ring owner (real ids are >= 0; SEED_OWNER
# is -1). Pinned against the relay constant by the property tests.
FREE_SEAT = -2


class RoundView(NamedTuple):
    """One round's cohort, as fixed-size host arrays (A = seat count)."""
    seat_ids: np.ndarray     # (A,) int32: external id per seat (FREE_SEAT)
    active: np.ndarray       # (A,)  bool: seat holds a non-departed client
    mask: np.ndarray         # (A,)  bool: participates this round
    evicted: np.ndarray      # (E,) int32: owners LRU-evicted at round start


@dataclass(frozen=True)
class StreamingPopulation:
    """Arrival-schedule parameters (see module docstring)."""
    k: int = 2                       # participants per round (fixed k)
    rate: float = 2.0                # expected arrivals per round
    p_leave: float = 0.1             # per-round departure probability
    population: int = 2**31 - 1      # distinct external-id space
    seed: int = 0
    name: str = "stream"

    def __post_init__(self):
        if self.k < 1 or self.rate < 0 or not (0 <= self.p_leave <= 1):
            raise ValueError(f"bad streaming-population spec: {self}")
        if self.population < 1:
            raise ValueError("population must be positive")

    def table(self, n_seats: int) -> "CohortTable":
        return CohortTable(self, n_seats)


class CohortTable:
    """Bounded active-cohort table with LRU owner eviction (host-side)."""

    def __init__(self, pop: StreamingPopulation, n_seats: int):
        assert n_seats >= 1, n_seats
        self.pop = pop
        self.n_seats = n_seats
        self.seat_ids = np.full((n_seats,), FREE_SEAT, np.int32)
        self.active = np.zeros((n_seats,), bool)
        self.last_active = np.full((n_seats,), -1, np.int64)
        self.next_id = 0
        self.dropped = 0                 # arrivals refused (all seats active)
        self._rounds: List[RoundView] = []

    def round(self, r: int) -> RoundView:
        """The cohort view for round r (replays forward as needed)."""
        while len(self._rounds) <= r:
            self._rounds.append(self._step(len(self._rounds)))
        return self._rounds[r]

    def nbytes(self) -> int:
        """Table memory — O(seats), independent of the population."""
        return (self.seat_ids.nbytes + self.active.nbytes
                + self.last_active.nbytes)

    def _step(self, r: int) -> RoundView:
        pop, A = self.pop, self.n_seats
        rng = np.random.default_rng([pop.seed, 0x5EA7, r])

        # 1. departures (drawn for every seat, applied to active ones, so
        #    the RNG stream does not depend on the mutable table state)
        leave = rng.random(A) < pop.p_leave
        self.active &= ~leave

        # 2. arrivals
        evicted: List[int] = []
        for _ in range(int(rng.poisson(pop.rate))):
            cid = self.next_id % pop.population
            self.next_id += 1
            seated = np.nonzero(self.seat_ids == cid)[0]
            if seated.size:                       # cycled id rejoins in place
                self.active[seated[0]] = True
                continue
            free = np.nonzero(self.seat_ids == FREE_SEAT)[0]
            if free.size:
                seat = int(free[0])
            else:
                idle = np.nonzero(~self.active)[0]
                if not idle.size:                 # every seat active: refuse
                    self.dropped += 1
                    continue
                seat = int(idle[np.argmin(self.last_active[idle])])   # LRU
                evicted.append(int(self.seat_ids[seat]))
            self.seat_ids[seat] = cid
            self.active[seat] = True
            self.last_active[seat] = r            # admission counts as activity
        # 3. participation: k of the active seats, uniform w/o replacement
        mask = np.zeros((A,), bool)
        idx = np.nonzero(self.active)[0]
        if idx.size:
            take = min(pop.k, idx.size)
            mask[rng.choice(idx, size=take, replace=False)] = True
            self.last_active[mask] = r
        return RoundView(seat_ids=self.seat_ids.copy(),
                         active=self.active.copy(), mask=mask,
                         evicted=np.asarray(evicted, np.int32))


def get_arrivals(spec: Union[str, StreamingPopulation, None],
                 ) -> Optional[StreamingPopulation]:
    """Resolve an arrival-schedule spec: None | instance |
    "stream[:k[,rate[,p_leave[,population[,seed]]]]]"."""
    if spec is None or isinstance(spec, StreamingPopulation):
        return spec
    name, args = parse_spec(spec, "arrival schedule",
                            {"stream": StreamingPopulation})
    kw = {}
    for field_name, cast, val in zip(
            ("k", "rate", "p_leave", "population", "seed"),
            (int, float, float, int, int), args):
        kw[field_name] = cast(val)
    return StreamingPopulation(**kw)
