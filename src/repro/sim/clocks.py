"""ClockModel — deterministic virtual-time client clocks.

Cross-device clients do not share a wall clock: an upload produced in round
r arrives at the server some rounds later (slow hardware, duty-cycled
radios, flaky links). A `ClockModel` captures that lateness as a pure
function of `(client_id, round_idx)`:

    delays(round_idx, n_clients) -> (N,) int array, each in [0, d_max]

where entry i is the COMMIT DELAY of client i's round-`round_idx` upload:
the upload is appended to the relay at round `round_idx + delay` (delay 0 =
the synchronous behavior). Bounding delays by `d_max` is what keeps the
engines' pending-upload buffers fixed-shape and jittable (see
repro.relay.events); `d_max = 0` degenerates to today's synchronous round.

Determinism is the load-bearing property, exactly as for participation
schedules: delays depend only on the model's parameters and the round
index — never on call order or hidden RNG state — so the sequential oracle
and the vectorized engine independently derive identical event timelines
and stay bit-exact equivalence-testable.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.specs import parse_spec


class ClockModel:
    name: str = "abstract"
    d_max: int = 0

    def delays(self, round_idx: int, n_clients: int) -> np.ndarray:
        """(N,) int64 commit delays for uploads born this round."""
        raise NotImplementedError


@dataclass(frozen=True)
class HomogeneousClock(ClockModel):
    """Every client commits with the same constant delay (delay 0 = the
    synchronous fleet). `d_max` may exceed `delay` to force the async
    pending-buffer machinery while all delays are still 0 — the bit-compat
    probe the tests use."""
    delay: int = 0
    d_max: int = -1          # -1 -> delay
    name: str = "homogeneous"

    def __post_init__(self):
        assert self.delay >= 0, self.delay
        if self.d_max < 0:
            object.__setattr__(self, "d_max", self.delay)
        assert self.delay <= self.d_max, (self.delay, self.d_max)

    def delays(self, round_idx: int, n_clients: int) -> np.ndarray:
        return np.full((n_clients,), self.delay, np.int64)


@dataclass(frozen=True)
class LognormalClock(ClockModel):
    """Straggler fleet: each client has a persistent speed drawn once from
    a lognormal (the classic heavy-tailed device-speed distribution), plus
    i.i.d. per-round jitter; delays are the rounded slowdown over the
    fastest client, clipped to d_max. A few clients are consistently slow
    (the stragglers), most commit immediately."""
    d_max: int = 4
    sigma: float = 1.0
    jitter: float = 0.25
    seed: int = 0
    name: str = "lognormal"

    def __post_init__(self):
        assert self.d_max >= 0, self.d_max

    def _base(self, n_clients: int) -> np.ndarray:
        """Per-client persistent slowdown in [0, inf): round-independent."""
        rng = np.random.default_rng([self.seed, 0x10c])
        return np.exp(self.sigma * rng.standard_normal(n_clients)) - 1.0

    def delays(self, round_idx: int, n_clients: int) -> np.ndarray:
        rng = np.random.default_rng([self.seed, 0xde1a, round_idx])
        jit = 1.0 + self.jitter * rng.standard_normal(n_clients)
        d = np.rint(self._base(n_clients) * np.maximum(jit, 0.0))
        return np.clip(d, 0, self.d_max).astype(np.int64)


@dataclass(frozen=True)
class PeriodicClock(ClockModel):
    """Duty-cycled availability: client i's uplink window recurs every
    `period` rounds (phase i mod period). An upload born inside the window
    commits immediately; one born off-window waits for the next window —
    delay = rounds until the client's next uplink slot, capped at d_max."""
    d_max: int = 4
    period: int = 3
    name: str = "periodic"

    def __post_init__(self):
        assert self.period > 0 and self.d_max >= 0

    def delays(self, round_idx: int, n_clients: int) -> np.ndarray:
        i = np.arange(n_clients)
        wait = (i - round_idx) % self.period     # rounds to next open window
        return np.minimum(wait, self.d_max).astype(np.int64)


def get_clock(spec, seed: int = 0):
    """Parse a CLI-style clock spec into a ClockModel (or pass one through).

    Specs: None (synchronous) | "none" | "homogeneous[:delay]" |
    "lognormal[:d_max[,sigma]]" | "periodic[:d_max[,period]]", e.g.
    "lognormal:4" or "periodic:2,3". Returns None for the synchronous
    fleet so callers can branch on `clock is None or clock.d_max == 0`.
    """
    if spec is None:
        return None
    if isinstance(spec, ClockModel):
        return spec
    name, args = parse_spec(
        spec, "clock model",
        ("none", "homogeneous", "lognormal", "periodic"),
        aliases={"sync": "none"})
    if name == "none":
        return None
    if name == "homogeneous":
        return HomogeneousClock(delay=int(args[0]) if args else 0)
    if name == "lognormal":
        return LognormalClock(d_max=int(args[0]) if args else 4,
                              sigma=float(args[1]) if len(args) > 1 else 1.0,
                              seed=seed)
    # periodic
    return PeriodicClock(d_max=int(args[0]) if args else 4,
                         period=int(args[1]) if len(args) > 1 else 3)


@dataclass(frozen=True)
class PeriodicSyncClock(ClockModel):
    """Duty-cycled DOWNLOAD staleness — the time-forward mirror of
    `PeriodicClock`: client i last completed a sync at its most recent
    window (phase i mod period), so the snapshot it trains against in
    round t is `(t − i) mod period` rounds stale — age GROWS 0, 1, ...,
    period−1 between windows and resets at the next sync, capped at
    d_max. (`PeriodicClock`'s rounds-UNTIL-next-window delay is correct
    for uploads but would make a downloader's observed history run
    backwards in time.)"""
    d_max: int = 4
    period: int = 3
    name: str = "periodic_sync"

    def __post_init__(self):
        assert self.period > 0 and self.d_max >= 0

    def delays(self, round_idx: int, n_clients: int) -> np.ndarray:
        i = np.arange(n_clients)
        since = (round_idx - i) % self.period    # rounds since last window
        return np.minimum(since, self.d_max).astype(np.int64)


# Seed fold separating the download-lag clock from the upload clock: the
# same seed (and even the same spec string) must yield DECORRELATED upload
# and download lateness — a device's radio being busy on the uplink says
# nothing about how stale its last sync is.
_DOWNLOAD_SEED_FOLD = 0xD1


def get_download_clock(spec, seed: int = 0):
    """Parse a DOWNLOAD-lag clock: same model zoo and spec strings as
    `get_clock`, but entry i of `delays(t, N)` is how many rounds STALE
    client i's relay snapshot is when it trains in round t — it reads the
    snapshot its round-`t − d` self would have read fresh (the post-merge
    state of round `t − d − 1`, via the relay history ring,
    repro.relay.history). `d_max` bounds the lag, so engines retain
    `H_max = d_max + 1` snapshots; delay 0 (or None) is today's
    round-fresh download. A ClockModel instance passes through unchanged;
    string specs are seeded through an independent fold so upload and
    download clocks built from one seed decorrelate, and "periodic"
    resolves to `PeriodicSyncClock` (rounds SINCE the last sync window —
    staleness must grow between syncs, not count down)."""
    if isinstance(spec, ClockModel):
        return spec
    c = get_clock(spec, seed=seed ^ _DOWNLOAD_SEED_FOLD)
    if isinstance(c, PeriodicClock):
        return PeriodicSyncClock(d_max=c.d_max, period=c.period)
    return c
