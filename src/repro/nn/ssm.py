"""Mamba2 block via the chunked SSD algorithm (TPU-native form).

The GPU Mamba2 kernel is a fused warp-level scan; the TPU-idiomatic
equivalent is the SSD block-decomposition: intra-chunk work becomes dense
(Q×Q)·(Q×P) matmuls on the MXU, inter-chunk state is a short lax.scan over
S/Q affine steps. Recurrence (per head h, scalar A):

    h_t = exp(A·dt_t) h_{t-1} + dt_t · B_t ⊗ x_t      (state: (P, N))
    y_t = C_t · h_t + D ⊙ x_t

Decode keeps (conv_state, ssm_state) in the cache and does the O(1) update.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn import layers


def init_mamba2(key, cfg, dtype):
    d, di, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    H, P, cw = cfg.mamba_heads, cfg.mamba_head_dim, cfg.ssm_conv
    conv_ch = di + 2 * N
    ks = layers.split(key, 4)
    return {
        "w_in": layers.dense_init(ks[0], d, 2 * di + 2 * N + H, dtype),
        "conv_w": (jax.random.normal(ks[1], (cw, conv_ch)) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "out_norm": layers.init_rmsnorm(di, dtype),
        "w_out": layers.dense_init(ks[2], di, d, dtype),
    }


def _split_proj(p, cfg, x):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.mamba_heads
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["w_in"])
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * N]
    dt = zxbcdt[..., di + di + 2 * N:]                       # (B,S,H)
    return z, xbc, dt


def _causal_conv(w, b, x):
    """Depthwise causal conv. x (B,S,C); w (cw,C)."""
    cw = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(cw))
    return jax.nn.silu(out + b[None, None, :])


def ssd_chunked(xh, Bm, Cm, dt, A, Q: int, h0=None):
    """Chunked SSD scan.

    xh (B,S,H,P); Bm/Cm (B,S,N); dt (B,S,H) (post-softplus); A (H,) negative.
    Returns y (B,S,H,P) float32 and final state (B,H,P,N).
    """
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    Q = min(Q, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    f32 = jnp.float32

    a = (dt.astype(f32) * A[None, None, :])                  # (B,S,H) negative
    xh = xh.astype(f32).reshape(Bsz, nc, Q, H, P)
    Bc = Bm.astype(f32).reshape(Bsz, nc, Q, N)
    Cc = Cm.astype(f32).reshape(Bsz, nc, Q, N)
    dtc = dt.astype(f32).reshape(Bsz, nc, Q, H)
    ac = a.reshape(Bsz, nc, Q, H)
    cums = jnp.cumsum(ac, axis=2)                            # inclusive
    total = cums[:, :, -1, :]                                # (B,nc,H)

    # --- intra-chunk (dense, MXU) ---
    Gm = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)               # (B,nc,Q,Q)
    Ld = cums[:, :, :, None, :] - cums[:, :, None, :, :]     # (B,nc,Q,Q,H) i,j
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(Ld), 0.0)
    W = Gm[..., None] * L * dtc[:, :, None, :, :]            # weight (i,j,h)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", W, xh)

    # --- chunk summary states ---
    decay_to_end = jnp.exp(total[:, :, None, :] - cums)      # (B,nc,Q,H)
    Sc = jnp.einsum("bcjh,bcjn,bcjhp->bchpn",
                    dtc * decay_to_end, Bc, xh)              # (B,nc,H,P,N)

    # --- inter-chunk scan ---
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), f32)

    def body(h, inp):
        tot_c, S_c = inp                                     # (B,H), (B,H,P,N)
        h_next = jnp.exp(tot_c)[:, :, None, None] * h + S_c
        return h_next, h                                     # emit state *entering* chunk

    (h_final, h_enter) = jax.lax.scan(
        body, h0, (total.transpose(1, 0, 2), Sc.transpose(1, 0, 2, 3, 4)))
    h_enter = h_enter.transpose(1, 0, 2, 3, 4)               # (B,nc,H,P,N)

    y_inter = jnp.einsum("bcin,bcih,bchpn->bcihp",
                         Cc, jnp.exp(cums), h_enter)
    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    return y, h_final


def mamba2_block(p, cfg, x, *, return_cache: bool = False):
    """x (B,S,d) -> y (B,S,d) [, cache=(conv_state, ssm_state)]."""
    B, S, d = x.shape
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.mamba_heads, cfg.mamba_head_dim
    z, xbc, dt = _split_proj(p, cfg, x)
    xbc_conv = _causal_conv(p["conv_w"], p["conv_b"], xbc)
    xs = xbc_conv[..., :di].reshape(B, S, H, P)
    Bm = xbc_conv[..., di:di + N]
    Cm = xbc_conv[..., di + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])
    y, h_final = ssd_chunked(xs, Bm, Cm, dt, A, cfg.ssm_chunk)
    y = y + p["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, S, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = layers.rmsnorm(p["out_norm"], y, cfg.norm_eps)
    y = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    if return_cache:
        conv_state = xbc[:, -(cfg.ssm_conv - 1):, :]         # last cw-1 inputs
        return y, (conv_state, h_final)
    return y


def mamba2_decode(p, cfg, x, cache):
    """One-token decode. x (B,1,d); cache=(conv_state (B,cw-1,C), h (B,H,P,N))."""
    B = x.shape[0]
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.mamba_heads, cfg.mamba_head_dim
    conv_state, h = cache
    z, xbc, dt = _split_proj(p, cfg, x)                      # (B,1,·)
    window = jnp.concatenate([conv_state, xbc], axis=1)      # (B,cw,C)
    conv = jnp.einsum("bwc,wc->bc", window, p["conv_w"]) + p["conv_b"]
    conv = jax.nn.silu(conv)[:, None, :]                     # (B,1,C)
    xs = conv[..., :di].reshape(B, H, P)
    Bm = conv[:, 0, di:di + N]
    Cm = conv[:, 0, di + N:]
    dt = jax.nn.softplus(dt[:, 0, :].astype(jnp.float32)
                         + p["dt_bias"][None, :])            # (B,H)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * A[None, :])                         # (B,H)
    h_new = (decay[:, :, None, None] * h
             + jnp.einsum("bh,bn,bhp->bhpn", dt, Bm.astype(jnp.float32),
                          xs.astype(jnp.float32)))
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), h_new)
    y = y + p["D"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, 1, di).astype(x.dtype) * jax.nn.silu(z)
    y = layers.rmsnorm(p["out_norm"], y, cfg.norm_eps)
    y = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    new_conv_state = window[:, 1:, :]
    return y, (new_conv_state, h_new)
