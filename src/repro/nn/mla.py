"""Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3).

Two execution forms:
  - expanded (train / prefill): latent kv is up-projected to per-head
    (k_nope, v); attention runs through the shared chunked online-softmax.
  - absorbed (decode): W_uk is absorbed into the query and W_uv into the
    output so attention runs directly against the compressed latent cache
    (B, S, kv_lora + qk_rope) — the MLA inference trick, which is what makes
    the 32k/500k decode caches small.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn import attention, layers, rope as rope_lib


def init_mla(key, cfg, dtype):
    d = cfg.d_model
    H = cfg.num_heads
    r_q, r_kv = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = layers.split(key, 8)
    p = {}
    if r_q:
        p["wq_a"] = layers.dense_init(ks[0], d, r_q, dtype)
        p["q_norm"] = layers.init_rmsnorm(r_q, dtype)
        p["wq_b"] = layers.dense_init(ks[1], r_q, H * (dn + dr), dtype)
    else:
        p["wq_b"] = layers.dense_init(ks[1], d, H * (dn + dr), dtype)
    p["wkv_a"] = layers.dense_init(ks[2], d, r_kv + dr, dtype)
    p["kv_norm"] = layers.init_rmsnorm(r_kv, dtype)
    p["wkv_b"] = layers.dense_init(ks[3], r_kv, H * (dn + dv), dtype)
    p["wo"] = layers.dense_init(ks[4], H * dv, d, dtype)
    return p


def _queries(p, cfg, x):
    B, S, _ = x.shape
    H, dn, dr = cfg.num_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    if cfg.q_lora_rank:
        cq = jnp.einsum("bsd,dr->bsr", x, p["wq_a"])
        cq = layers.rmsnorm(p["q_norm"], cq, cfg.norm_eps)
        q = jnp.einsum("bsr,re->bse", cq, p["wq_b"])
    else:
        q = jnp.einsum("bsd,de->bse", x, p["wq_b"])
    q = q.reshape(B, S, H, dn + dr)
    return q[..., :dn], q[..., dn:]          # q_nope (B,S,H,dn), q_rope (B,S,H,dr)


def _latent_kv(p, cfg, x, positions):
    """-> c_kv (B,S,r_kv) normalized, k_rope (B,S,1,dr) rotated."""
    r_kv, dr = cfg.kv_lora_rank, cfg.qk_rope_dim
    kv = jnp.einsum("bsd,de->bse", x, p["wkv_a"])
    c_kv, k_rope = kv[..., :r_kv], kv[..., r_kv:]
    c_kv = layers.rmsnorm(p["kv_norm"], c_kv, cfg.norm_eps)
    k_rope = rope_lib.apply_rope(k_rope[:, :, None, :], positions,
                                 theta=cfg.rope_theta, kind="rope")
    return c_kv, k_rope


def mla_block(p, cfg, x, positions, *, window: int = 0, chunk: int = 512,
              return_cache: bool = False):
    """Expanded-form MLA over a full sequence (train / prefill)."""
    B, S, _ = x.shape
    H, dn, dr, dv = (cfg.num_heads, cfg.qk_nope_dim, cfg.qk_rope_dim,
                     cfg.v_head_dim)
    q_nope, q_rope = _queries(p, cfg, x)
    q_rope = rope_lib.apply_rope(q_rope, positions, theta=cfg.rope_theta,
                                 kind="rope")
    c_kv, k_rope = _latent_kv(p, cfg, x, positions)
    kv = jnp.einsum("bsr,re->bse", c_kv, p["wkv_b"]).reshape(B, S, H, dn + dv)
    k_nope, v = kv[..., :dn], kv[..., dn:]
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, H, dr))],
                        axis=-1)
    if S <= 2 * chunk:
        o = attention.full_attention(q, k, v, causal=True, window=window)
    else:
        o = attention.chunked_attention(q, k, v, causal=True, window=window,
                                        chunk=chunk)
    y = jnp.einsum("bse,ed->bsd", o.reshape(B, S, H * dv), p["wo"])
    if return_cache:
        cache = jnp.concatenate([c_kv, k_rope[:, :, 0, :]], axis=-1)
        return y, cache                       # (B,S,r_kv+dr)
    return y


def mla_decode(p, cfg, x, cache, positions, *, cache_index=None,
               masked: bool = False):
    """Absorbed-form one-token decode against the latent cache.

    cache: (B, Sc, r_kv + dr). With `masked=True` attention is restricted to
    slots <= cache_index (incremental serving). Returns (y, new_cache).
    """
    B = x.shape[0]
    H, dn, dr, dv = (cfg.num_heads, cfg.qk_nope_dim, cfg.qk_rope_dim,
                     cfg.v_head_dim)
    r_kv = cfg.kv_lora_rank
    q_nope, q_rope = _queries(p, cfg, x)                     # (B,1,H,·)
    q_rope = rope_lib.apply_rope(q_rope, positions, theta=cfg.rope_theta,
                                 kind="rope")
    c_new, kr_new = _latent_kv(p, cfg, x, positions)
    new_entry = jnp.concatenate([c_new, kr_new[:, :, 0, :]], axis=-1)
    if cache_index is None:
        cache_index = cache.shape[1] - 1
    cache = jax.lax.dynamic_update_slice(
        cache, new_entry.astype(cache.dtype), (0, cache_index, 0))
    c_kv, k_rope = cache[..., :r_kv], cache[..., r_kv:]      # (B,Sc,·)

    w_b = p["wkv_b"].reshape(r_kv, H, dn + dv)
    w_uk, w_uv = w_b[..., :dn], w_b[..., dn:]                # (r,H,dn),(r,H,dv)
    # absorb: q_lat[h] = q_nope[h] @ W_uk[:,h,:]^T  -> latent-space query
    q_lat = jnp.einsum("bshd,rhd->bshr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))             # (B,1,H,r)
    scale = (dn + dr) ** -0.5
    s = (jnp.einsum("bshr,bkr->bhsk", q_lat, c_kv.astype(jnp.float32))
         + jnp.einsum("bshd,bkd->bhsk", q_rope.astype(jnp.float32),
                      k_rope.astype(jnp.float32))) * scale   # (B,H,1,Sc)
    if masked:
        valid = jnp.arange(cache.shape[1]) <= cache_index
        s = jnp.where(valid[None, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhsk,bkr->bshr", w, c_kv.astype(jnp.float32))
    o = jnp.einsum("bshr,rhd->bshd", o_lat, w_uv.astype(jnp.float32))
    y = jnp.einsum("bse,ed->bsd", o.reshape(B, 1, H * dv).astype(x.dtype),
                   p["wo"])
    return y, cache
