"""xLSTM blocks: mLSTM (matrix memory, chunk-parallel) and sLSTM (scalar
memory, sequential lax.scan).

mLSTM recurrence (per head, state C: (P_v, P_k), normalizer n: (P_k,)):
    C_t = f_t C_{t-1} + i_t v_t k_t^T
    n_t = f_t n_{t-1} + i_t k_t
    y_t = (C_t q_t) / max(|n_t · q_t|, 1)
This is the SSD recurrence with (dt,B,C,x) := (i,k,q,v) and per-head k/q, so
we run the same chunked block-decomposition (dense MXU matmuls intra-chunk,
short scan inter-chunk); the normalizer rides along as an appended ones
column of v. sLSTM's stabilized exponential gating is inherently sequential
(running max m_t), so it uses lax.scan over time — faithful to the paper,
and the reason xLSTM-125m keeps sLSTM layers sparse (1-in-6 here).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.nn import layers


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def init_mlstm(key, cfg, dtype):
    d = cfg.d_model
    di = 2 * d                      # projection factor 2 (xLSTM paper)
    ks = layers.split(key, 8)
    return {
        "w_up": layers.dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, di)) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "wq": layers.dense_init(ks[2], di, di, dtype),
        "wk": layers.dense_init(ks[3], di, di, dtype),
        "wv": layers.dense_init(ks[4], di, di, dtype),
        "w_gates": layers.dense_init(ks[5], di, 2 * cfg.num_heads, dtype),
        "out_norm": layers.init_rmsnorm(di, dtype),
        "w_down": layers.dense_init(ks[6], di, d, dtype),
    }


def _conv_silu(w, b, x):
    cw = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(cw))
    return jax.nn.silu(out + b[None, None, :])


def mlstm_chunked(q, k, v, logf, logi, Q: int, state=None):
    """q,k,v (B,S,H,P); logf,logi (B,S,H). Returns y (B,S,H,P) f32, state.

    state = (C (B,H,Pv+1,Pk)) where row P_v is the normalizer.
    """
    B, S, H, P = q.shape
    f32 = jnp.float32
    Q = min(Q, S)
    assert S % Q == 0
    nc = S // Q
    ones = jnp.ones((B, S, H, 1), f32)
    va = jnp.concatenate([v.astype(f32), ones], axis=-1)     # (B,S,H,P+1)
    scale = P ** -0.5

    qc = (q.astype(f32) * scale).reshape(B, nc, Q, H, P)
    kc = k.astype(f32).reshape(B, nc, Q, H, P)
    vc = va.reshape(B, nc, Q, H, P + 1)
    ic = logi.astype(f32).reshape(B, nc, Q, H)
    fc = logf.astype(f32).reshape(B, nc, Q, H)
    cums = jnp.cumsum(fc, axis=2)
    total = cums[:, :, -1, :]

    Gm = jnp.einsum("bcihp,bcjhp->bcijh", qc, kc)            # (B,nc,Q,Q,H)
    Ld = cums[:, :, :, None, :] - cums[:, :, None, :, :] + ic[:, :, None, :, :]
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    W = jnp.where(causal[None, None, :, :, None], Gm * jnp.exp(Ld), 0.0)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", W, vc)

    decay_to_end = jnp.exp(total[:, :, None, :] - cums + ic)  # (B,nc,Q,H)
    Sc = jnp.einsum("bcjh,bcjhp,bcjhn->bchpn", decay_to_end, vc, kc)

    if state is None:
        state = jnp.zeros((B, H, P + 1, P), f32)

    def body(C, inp):
        tot_c, S_c = inp
        return jnp.exp(tot_c)[:, :, None, None] * C + S_c, C

    C_final, C_enter = jax.lax.scan(
        body, state, (total.transpose(1, 0, 2), Sc.transpose(1, 0, 2, 3, 4)))
    C_enter = C_enter.transpose(1, 0, 2, 3, 4)               # (B,nc,H,P+1,P)
    y_inter = jnp.einsum("bcihn,bcih,bchpn->bcihp",
                         qc, jnp.exp(cums), C_enter)
    ya = (y_intra + y_inter).reshape(B, S, H, P + 1)
    y = ya[..., :P] / jnp.maximum(jnp.abs(ya[..., P:]), 1.0)
    return y, C_final


def mlstm_block(p, cfg, x, *, return_cache: bool = False, cache=None,
                decode: bool = False):
    B, S, d = x.shape
    H = cfg.num_heads
    di = 2 * d
    P = di // H
    up = jnp.einsum("bsd,de->bse", x, p["w_up"])
    xm, z = up[..., :di], up[..., di:]
    if decode:
        conv_state, C = cache
        window = jnp.concatenate([conv_state, xm], axis=1)
        cw = p["conv_w"].shape[0]
        conv = jax.nn.silu(jnp.einsum("bwc,wc->bc", window, p["conv_w"])
                           + p["conv_b"])[:, None, :]
        new_conv_state = window[:, 1:, :]
    else:
        conv = _conv_silu(p["conv_w"], p["conv_b"], xm)
    q = jnp.einsum("bse,ef->bsf", conv, p["wq"]).reshape(B, S, H, P)
    k = jnp.einsum("bse,ef->bsf", conv, p["wk"]).reshape(B, S, H, P)
    v = jnp.einsum("bse,ef->bsf", xm, p["wv"]).reshape(B, S, H, P)
    gates = jnp.einsum("bse,eh->bsh", conv, p["w_gates"]).astype(jnp.float32)
    logi, fpre = gates[..., :H], gates[..., H:]
    logf = jax.nn.log_sigmoid(fpre)

    if decode:
        # O(1) recurrent update
        f32 = jnp.float32
        scale = P ** -0.5
        ones = jnp.ones((B, 1, H, 1), f32)
        va = jnp.concatenate([v.astype(f32), ones], axis=-1)[:, 0]  # (B,H,P+1)
        C_new = (jnp.exp(logf[:, 0])[:, :, None, None] * C
                 + jnp.exp(logi[:, 0])[:, :, None, None]
                 * jnp.einsum("bhp,bhn->bhpn", va, k.astype(f32)[:, 0]))
        qs = q.astype(f32)[:, 0] * scale
        ya = jnp.einsum("bhn,bhpn->bhp", qs, C_new)
        y = ya[..., :P] / jnp.maximum(jnp.abs(ya[..., P:]), 1.0)
        y = y[:, None]                                       # (B,1,H,P)
        new_cache = (new_conv_state, C_new)
    else:
        y, C_final = mlstm_chunked(q, k, v, logf, logi, cfg.ssm_chunk)
        new_cache = None
        if return_cache:
            conv_state = xm[:, -(cfg.ssm_conv - 1):, :]
            new_cache = (conv_state, C_final)
    y = y.reshape(B, S, di).astype(x.dtype) * jax.nn.silu(z)
    y = layers.rmsnorm(p["out_norm"], y, cfg.norm_eps)
    y = jnp.einsum("bse,ed->bsd", y, p["w_down"])
    if return_cache or decode:
        return y, new_cache
    return y


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def init_slstm(key, cfg, dtype):
    d = cfg.d_model
    H = cfg.num_heads
    P = d // H
    ks = layers.split(key, 4)
    return {
        "w_gates": layers.dense_init(ks[0], d, 4 * d, dtype),   # i,f,z,o per cell
        "r_gates": (jax.random.normal(ks[1], (4, H, P, P))
                    / math.sqrt(P)).astype(dtype),              # block-diag recurrence
        "out_norm": layers.init_rmsnorm(d, dtype),
        "w_up": layers.dense_init(ks[2], d, 2 * d, dtype),      # GLU ffn
        "w_down": layers.dense_init(ks[3], d, d, dtype),
    }


def slstm_scan(gx, r, state):
    """gx (B,S,4,d) input gate pre-activations; r (4,H,P,P) recurrence.

    state = (c, n, m, h): c,n,h (B,d); m (B,d). Returns h_seq (B,S,d), state.
    """
    B, S, four, d = gx.shape
    H, P = r.shape[1], r.shape[2]

    def step(carry, g_t):
        c, n, m, h = carry
        hh = h.reshape(B, H, P)
        rec = jnp.einsum("ghpq,bhq->bghp", r.astype(jnp.float32), hh)
        rec = rec.reshape(B, four, d)
        g = g_t.astype(jnp.float32) + rec
        i_pre, f_pre, z_pre, o_pre = g[:, 0], g[:, 1], g[:, 2], g[:, 3]
        m_new = jnp.maximum(f_pre + m, i_pre)
        i = jnp.exp(i_pre - m_new)
        f = jnp.exp(f_pre + m - m_new)
        c_new = f * c + i * jnp.tanh(z_pre)
        n_new = f * n + i
        h_new = jax.nn.sigmoid(o_pre) * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, m_new, h_new), h_new

    (c, n, m, h), hs = jax.lax.scan(step, state, gx.transpose(1, 0, 2, 3))
    return hs.transpose(1, 0, 2), (c, n, m, h)


def slstm_block(p, cfg, x, *, return_cache: bool = False, cache=None,
                decode: bool = False):
    B, S, d = x.shape
    gx = jnp.einsum("bsd,de->bse", x, p["w_gates"]).reshape(B, S, 4, d)
    if cache is None:
        z = jnp.zeros((B, d), jnp.float32)
        state = (z, z, jnp.full((B, d), -30.0, jnp.float32), z)
    else:
        state = cache
    hs, state = slstm_scan(gx, p["r_gates"], state)
    hs = layers.rmsnorm(p["out_norm"], hs.astype(x.dtype), cfg.norm_eps)
    up = jnp.einsum("bsd,de->bse", hs, p["w_up"])
    g, u = up[..., :d], up[..., d:]
    y = jnp.einsum("bsd,de->bse", jax.nn.silu(g) * u, p["w_down"])
    if return_cache or decode:
        return y, state
    return y
