from repro.nn import attention, layers, mla, moe, rope, ssm, xlstm  # noqa: F401
