"""GQA attention: chunked online-softmax (memory-bounded) + decode step.

The chunked path is the portable JAX implementation used for training,
prefill and the multi-pod dry-run (memory O(S·Ck) instead of O(S²)); the
Pallas flash-attention kernel in kernels/flash_attention.py implements the
same math with explicit VMEM tiling for TPU and is validated against
kernels/ref.py in interpret mode.

Layouts: x (B, S, D); q (B, S, H, hd); k/v (B, S, G, hd) with G = kv heads.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn import layers, rope as rope_lib

NEG_INF = -1e30


def init_gqa(key, d_model: int, num_heads: int, num_kv_heads: int,
             head_dim: int, dtype):
    k1, k2, k3, k4 = layers.split(key, 4)
    return {
        "wq": layers.dense_init(k1, d_model, num_heads * head_dim, dtype),
        "wk": layers.dense_init(k2, d_model, num_kv_heads * head_dim, dtype),
        "wv": layers.dense_init(k3, d_model, num_kv_heads * head_dim, dtype),
        "wo": layers.dense_init(k4, num_heads * head_dim, d_model, dtype),
    }


def qkv(params, x, num_heads: int, num_kv_heads: int, head_dim: int):
    B, S, _ = x.shape
    q = jnp.einsum("bsd,de->bse", x, params["wq"]).reshape(B, S, num_heads, head_dim)
    k = jnp.einsum("bsd,de->bse", x, params["wk"]).reshape(B, S, num_kv_heads, head_dim)
    v = jnp.einsum("bsd,de->bse", x, params["wv"]).reshape(B, S, num_kv_heads, head_dim)
    return q, k, v


# ---------------------------------------------------------------------------
# chunked online-softmax attention (full / causal / sliding window)
# ---------------------------------------------------------------------------
def chunked_attention(q, k, v, *, causal: bool, window: int = 0,
                      chunk: int = 512, q_offset: int = 0):
    """q (B,Sq,H,hd); k,v (B,Sk,G,hd). Returns (B,Sq,H,hd).

    Scans over KV chunks with a running (max, sum, acc) — memory bounded by
    one (B,G,Hr,Sq,Ck) score block. `q_offset` is the absolute position of
    q[0] (for prefill continuation); kv positions start at 0.
    """
    B, Sq, H, hd = q.shape
    _, Sk, G, _ = k.shape
    hv = v.shape[-1]
    Hr = H // G
    chunk = min(chunk, Sk)
    assert Sk % chunk == 0, (Sk, chunk)
    n_chunks = Sk // chunk

    qf = (q.reshape(B, Sq, G, Hr, hd) * (hd ** -0.5)).astype(jnp.float32)
    kf = k.transpose(1, 0, 2, 3).reshape(n_chunks, chunk, B, G, hd)
    vf = v.transpose(1, 0, 2, 3).reshape(n_chunks, chunk, B, G, hv)

    q_pos = q_offset + jnp.arange(Sq, dtype=jnp.int32)

    def body(carry, inp):
        m, l, acc = carry
        j, kj, vj = inp
        kj = kj.transpose(1, 2, 0, 3)                 # (B,G,Ck,hd)
        vj = vj.transpose(1, 2, 0, 3)
        s = jnp.einsum("bqghd,bgkd->bgqhk", qf, kj.astype(jnp.float32))
        k_pos = j * chunk + jnp.arange(chunk, dtype=jnp.int32)
        mask = jnp.ones((Sq, chunk), dtype=bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window:
            mask &= (q_pos[:, None] - k_pos[None, :]) < window
        s = jnp.where(mask[None, None, :, None, :], s, NEG_INF)  # (B,G,Sq,Hr,Ck)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        scale = jnp.exp(m - m_new)
        l_new = l * scale + jnp.sum(p, axis=-1)
        acc_new = acc * scale[..., None] + jnp.einsum(
            "bgqhk,bgkd->bgqhd", p, vj.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, G, Sq, Hr), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, G, Sq, Hr), jnp.float32)
    a0 = jnp.zeros((B, G, Sq, Hr, hv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.arange(n_chunks, dtype=jnp.int32), kf, vf))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3, 4).reshape(B, Sq, H, hv).astype(q.dtype)


def full_attention(q, k, v, *, causal: bool, window: int = 0, q_offset: int = 0):
    """Naive reference (materializes scores); used for short KV / oracles."""
    B, Sq, H, hd = q.shape
    _, Sk, G, _ = k.shape
    hv = v.shape[-1]
    Hr = H // G
    qf = (q.reshape(B, Sq, G, Hr, hd) * (hd ** -0.5)).astype(jnp.float32)
    s = jnp.einsum("bqghd,bkgd->bgqhk", qf, k.astype(jnp.float32))
    q_pos = q_offset + jnp.arange(Sq, dtype=jnp.int32)
    k_pos = jnp.arange(Sk, dtype=jnp.int32)
    mask = jnp.ones((Sq, Sk), dtype=bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window:
        mask &= (q_pos[:, None] - k_pos[None, :]) < window
    s = jnp.where(mask[None, None, :, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgqhk,bkgd->bgqhd", p, v.astype(jnp.float32))
    return out.transpose(0, 2, 1, 3, 4).reshape(B, Sq, H, hv).astype(q.dtype)


# ---------------------------------------------------------------------------
# module-level forward paths
# ---------------------------------------------------------------------------
def gqa_block(params, x, positions, *, num_heads, num_kv_heads, head_dim,
              rope_kind, rope_theta, causal=True, window=0, chunk=512,
              return_kv=False, kv=None):
    """Self/cross attention on a full sequence. kv: optional (k, v) override
    (cross-attention). Returns y (B,S,D) [, (k, v)]."""
    q, k_new, v_new = qkv(params, x, num_heads, num_kv_heads, head_dim)
    if kv is None:
        if rope_kind != "none":
            q = rope_lib.apply_rope(q, positions, theta=rope_theta, kind=rope_kind)
            k_new = rope_lib.apply_rope(k_new, positions, theta=rope_theta, kind=rope_kind)
        k, v = k_new, v_new
    else:
        k, v = kv
    Sk = k.shape[1]
    if Sk <= 2 * chunk or Sk % chunk != 0:
        o = full_attention(q, k, v, causal=causal, window=window)
    else:
        o = chunked_attention(q, k, v, causal=causal, window=window, chunk=chunk)
    B, S = x.shape[:2]
    y = jnp.einsum("bse,ed->bsd", o.reshape(B, S, num_heads * head_dim),
                   params["wo"])
    if return_kv:
        return y, (k, v)
    return y


def gqa_decode(params, x, cache_k, cache_v, positions, *, num_heads,
               num_kv_heads, head_dim, rope_kind, rope_theta,
               cache_index=None, window: int = 0, masked: bool = False):
    """One-token decode. x (B,1,D); cache_k/v (B,Sc,G,hd) pre-filled.

    `cache_index` is the slot the new token's K/V overwrite (defaults to the
    last slot — the steady-state dry-run semantics where every slot is
    valid). With `masked=True`, attention is restricted to slots
    <= cache_index (incremental generation into a fixed-size cache; the
    serving path). With `window`, the cache is a ring buffer of size
    `window`. Keys are stored already rotated. Returns (y, new_k, new_v).
    """
    B = x.shape[0]
    q, k1, v1 = qkv(params, x, num_heads, num_kv_heads, head_dim)
    if rope_kind != "none":
        q = rope_lib.apply_rope(q, positions, theta=rope_theta, kind=rope_kind)
        k1 = rope_lib.apply_rope(k1, positions, theta=rope_theta, kind=rope_kind)
    if cache_index is None:
        cache_index = cache_k.shape[1] - 1
    k = jax.lax.dynamic_update_slice(cache_k, k1.astype(cache_k.dtype),
                                     (0, cache_index, 0, 0))
    v = jax.lax.dynamic_update_slice(cache_v, v1.astype(cache_v.dtype),
                                     (0, cache_index, 0, 0))
    if masked:
        Sc = k.shape[1]
        G = num_kv_heads
        Hr = num_heads // G
        qf = (q.reshape(B, 1, G, Hr, head_dim)
              * (head_dim ** -0.5)).astype(jnp.float32)
        s = jnp.einsum("bqghd,bkgd->bgqhk", qf, k.astype(jnp.float32))
        valid = jnp.arange(Sc) <= cache_index
        s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bgqhk,bkgd->bgqhd", p, v.astype(jnp.float32))
        o = o.transpose(0, 2, 1, 3, 4).reshape(B, 1, num_heads, head_dim)
        o = o.astype(q.dtype)
    else:
        # steady-state decode: every cache slot valid (dry-run semantics);
        # ring-buffer order does not matter for softmax(qk)v.
        o = full_attention(q, k, v, causal=False)
    y = jnp.einsum("bse,ed->bsd", o.reshape(B, 1, num_heads * head_dim),
                   params["wo"])
    return y, k, v
