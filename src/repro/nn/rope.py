"""Rotary position embeddings: standard, 2D-partial (ChatGLM), M-RoPE (Qwen2-VL).

Inputs use the half-split convention: x[..., :r/2] and x[..., r/2:] form the
rotation pairs (llama convention). `positions` is (B, S) int32 for rope/rope2d
and (B, S, 3) [t, h, w] for mrope (text tokens use t == h == w, in which case
M-RoPE coincides with standard RoPE — the property test checks this).
"""
from __future__ import annotations

import jax.numpy as jnp

# M-RoPE frequency-band split across (t, h, w), in units of freq indices of
# the half-dim. Scaled to the actual rot_dim at call time (Qwen2-VL uses
# [16, 24, 24] for rot half-dim 64 -> fractions (0.25, 0.375, 0.375)).
MROPE_FRACTIONS = (0.25, 0.375, 0.375)


def _freqs(rot_half: int, theta: float):
    i = jnp.arange(rot_half, dtype=jnp.float32)
    return theta ** (-2.0 * i / (2.0 * rot_half))


def _cos_sin(positions, theta: float, rot_half: int, kind: str):
    """-> cos, sin of shape (B, S, rot_half) float32."""
    inv = _freqs(rot_half, theta)                              # (rot_half,)
    if kind == "mrope":
        assert positions.ndim == 3 and positions.shape[-1] == 3
        n_t = int(round(MROPE_FRACTIONS[0] * rot_half))
        n_h = int(round(MROPE_FRACTIONS[1] * rot_half))
        n_w = rot_half - n_t - n_h
        sect = jnp.concatenate([
            jnp.zeros((n_t,), jnp.int32),
            jnp.ones((n_h,), jnp.int32),
            jnp.full((n_w,), 2, jnp.int32)])
        pos = jnp.take_along_axis(
            positions.astype(jnp.float32),
            jnp.broadcast_to(sect[None, None, :], positions.shape[:2] + (rot_half,)),
            axis=-1)                                           # (B,S,rot_half)
        ang = pos * inv[None, None, :]
    else:
        pos = positions.astype(jnp.float32)                    # (B,S)
        ang = pos[..., None] * inv[None, None, :]
    return jnp.cos(ang), jnp.sin(ang)


def rot_dim_for(kind: str, head_dim: int) -> int:
    if kind == "rope2d":
        return head_dim // 2            # ChatGLM: rotary on half the dims
    return head_dim


def apply_rope(x, positions, *, theta: float, kind: str):
    """x: (B, S, H, D). Returns same shape/dtype with rotary applied."""
    if kind == "none":
        return x
    d = x.shape[-1]
    r = rot_dim_for(kind, d)
    half = r // 2
    cos, sin = _cos_sin(positions, theta, half, kind)          # (B,S,half)
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    xr, xp = x[..., :r].astype(jnp.float32), x[..., r:]
    x1, x2 = xr[..., :half], xr[..., half:]
    rot = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return jnp.concatenate([rot.astype(x.dtype), xp], axis=-1) if r < d \
        else rot.astype(x.dtype)


def default_positions(batch: int, seq: int, kind: str, offset=0):
    pos = offset + jnp.arange(seq, dtype=jnp.int32)[None, :]
    pos = jnp.broadcast_to(pos, (batch, seq))
    if kind == "mrope":
        return jnp.broadcast_to(pos[..., None], (batch, seq, 3))
    return pos
