"""Mixture-of-Experts layer: top-k router + capacity-based dispatch.

TPU adaptation: GPU MoE kernels scatter tokens with atomics; on TPU we use
the dropless-ish capacity dispatch — per batch-row position-in-expert via a
one-hot cumsum, a scatter into an (E, capacity, d) buffer, one batched einsum
over stacked expert weights (MXU-friendly), and a gather back. Active FLOPs
are E·cap·d·f ≈ cf·k·T·d·f (true top-k compute, not dense all-expert compute,
so the roofline MODEL_FLOPS/HLO_FLOPs ratio stays honest).

Stacked expert weight names end in `_e` — sharding.param_spec shards their
d_ff dim over the model axis (expert-parallel E-sharding is a §Perf variant).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro import sharding
from repro.nn import layers


def init_moe(key, d_model: int, num_experts: int, moe_d_ff: int,
             num_shared: int, dtype):
    ks = layers.split(key, 5)
    E, f = num_experts, moe_d_ff
    scale = 1.0 / math.sqrt(d_model)
    p = {
        "router": layers.dense_init(ks[0], d_model, E, jnp.float32, scale=scale),
        "w_gate_e": (jax.random.normal(ks[1], (E, d_model, f)) * scale).astype(dtype),
        "w_up_e": (jax.random.normal(ks[2], (E, d_model, f)) * scale).astype(dtype),
        "w_down_e": (jax.random.normal(ks[3], (E, f, d_model)) / math.sqrt(f)).astype(dtype),
    }
    if num_shared:
        p["shared"] = layers.init_swiglu(ks[4], d_model, moe_d_ff * num_shared,
                                         dtype)
    return p


def capacity(seq: int, k: int, num_experts: int, cf: float) -> int:
    return max(1, int(math.ceil(cf * seq * k / num_experts)))


def route(router_w, x, k: int):
    """x (B,S,d) -> probs (B,S,k), idx (B,S,k) int32, aux_loss scalar."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                  # (B,S,E)
    top_p, top_i = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux loss
    E = router_w.shape[-1]
    me = jnp.mean(probs, axis=(0, 1))                        # mean prob/expert
    one_hot = jax.nn.one_hot(top_i, E, dtype=jnp.float32)    # (B,S,k,E)
    ce = jnp.mean(jnp.sum(one_hot, axis=2), axis=(0, 1)) / k # frac tokens/expert
    aux = E * jnp.sum(me * ce)
    return top_p, top_i, aux


def _dispatch_row(x_row, idx_row, cap: int, E: int):
    """x_row (S,d); idx_row (S,k) -> buffer (E*cap, d), scatter idx (S,k)."""
    S, k = idx_row.shape
    flat = idx_row.reshape(-1)                               # (S*k,)
    onehot = jax.nn.one_hot(flat, E, dtype=jnp.int32)        # (S*k, E)
    pos = jnp.cumsum(onehot, axis=0) - 1                     # position in expert
    pos = jnp.sum(pos * onehot, axis=-1)                     # (S*k,)
    valid = pos < cap
    slot = jnp.where(valid, flat * cap + pos, E * cap)       # overflow -> dump
    buf = jnp.zeros((E * cap + 1, x_row.shape[-1]), x_row.dtype)
    vals = jnp.repeat(x_row, k, axis=0)                      # (S*k, d)
    buf = buf.at[slot].add(vals)
    return buf[:-1], slot.reshape(S, k), valid.reshape(S, k)


def moe_block(p, x, *, num_experts: int, k: int, cf: float,
              num_shared: int) -> Tuple[jax.Array, jax.Array]:
    """x (B,S,d) -> (y (B,S,d), aux_loss)."""
    B, S, d = x.shape
    E = num_experts
    cap = capacity(S, k, E, cf)
    top_p, top_i, aux = route(p["router"], x, k)

    buf, slot, valid = jax.vmap(
        lambda xr, ir: _dispatch_row(xr, ir, cap, E))(x, top_i)
    buf = buf.reshape(B, E, cap, d)
    if sharding.hint("moe_ep"):
        # expert-parallel §Perf variant: dispatch buffer and expert compute
        # sharded over experts on the model axis (all-to-all style routing)
        buf = sharding.constrain(buf, "data", "model", None, None)
    elif sharding.hint("moe_dp"):
        # dp_only/zero1: keep the dispatch fully batch-local — without this
        # GSPMD replicates the capacity einsum when expert weights are
        # replicated and only the row dim is sharded (measured 87×)
        buf = sharding.constrain(buf, ("data", "model"), None, None, None)
    g = jnp.einsum("becd,edf->becf", buf, p["w_gate_e"])
    u = jnp.einsum("becd,edf->becf", buf, p["w_up_e"])
    h = jax.nn.silu(g) * u
    out = jnp.einsum("becf,efd->becd", h, p["w_down_e"])
    if sharding.hint("moe_ep"):
        out = sharding.constrain(out, "data", "model", None, None)
    elif sharding.hint("moe_dp"):
        out = sharding.constrain(out, ("data", "model"), None, None, None)
    out = out.reshape(B, E * cap, d)

    def _gather_row(o_row, slot_row):
        safe = jnp.minimum(slot_row.reshape(-1), E * cap - 1)
        return o_row[safe].reshape(S, -1, d)                 # (S,k,d)
    y_k = jax.vmap(_gather_row)(out, slot)                   # (B,S,k,d)
    w = (top_p * valid.astype(top_p.dtype))[..., None].astype(y_k.dtype)
    y = jnp.sum(y_k * w, axis=2)

    if num_shared:
        y = y + layers.swiglu(p["shared"], x)
    return y, aux.astype(jnp.float32)
