"""Basic functional layers: init helpers, norms, linear, embedding, MLPs.

Everything is a pair of functions: `init_*` returning a dict-of-arrays param
tree, and an apply function taking (params, inputs). No module objects — the
pytrees compose naturally with jax.jit / scan / grad and keep the sharding
rules (sharding.py) path-addressable.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32)
            * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), dtype=jnp.float32)
            * 0.02).astype(dtype)


def split(key, n):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def init_rmsnorm(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def init_layernorm(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype=dtype),
            "bias": jnp.zeros((d,), dtype=dtype)}


def layernorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(dt)


def init_norm(kind: str, d: int, dtype):
    return init_rmsnorm(d, dtype) if kind == "rmsnorm" else init_layernorm(d, dtype)


def apply_norm(kind: str, params, x, eps: float):
    return rmsnorm(params, x, eps) if kind == "rmsnorm" else layernorm(params, x, eps)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def init_swiglu(key, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = split(key, 3)
    return {"w_gate": dense_init(k1, d_model, d_ff, dtype),
            "w_up": dense_init(k2, d_model, d_ff, dtype),
            "w_down": dense_init(k3, d_ff, d_model, dtype)}


def swiglu(params, x):
    g = jnp.einsum("...d,df->...f", x, params["w_gate"])
    u = jnp.einsum("...d,df->...f", x, params["w_up"])
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, params["w_down"])


def init_gelu_mlp(key, d_model: int, d_ff: int, dtype):
    k1, k2 = split(key, 2)
    return {"w_up": dense_init(k1, d_model, d_ff, dtype),
            "b_up": jnp.zeros((d_ff,), dtype=dtype),
            "w_down": dense_init(k2, d_ff, d_model, dtype),
            "b_down": jnp.zeros((d_model,), dtype=dtype)}


def gelu_mlp(params, x):
    h = jnp.einsum("...d,df->...f", x, params["w_up"]) + params["b_up"]
    h = jax.nn.gelu(h)
    return jnp.einsum("...f,fd->...d", h, params["w_down"]) + params["b_down"]


def init_mlp(kind: str, key, d_model: int, d_ff: int, dtype):
    return (init_swiglu(key, d_model, d_ff, dtype) if kind == "swiglu"
            else init_gelu_mlp(key, d_model, d_ff, dtype))


def apply_mlp(kind: str, params, x):
    return swiglu(params, x) if kind == "swiglu" else gelu_mlp(params, x)
