"""Flat ring relay — one global ring, uniform with-replacement sampling.

This is the seed implementation, moved verbatim from the retired
`core/server.py`: a single (cap, C, d') observation ring with per-slot
validity/owner and uniform sampling over other clients' slots. It is the
bit-compatibility anchor — `FlatRelay` must evolve byte-identical state to
the pre-subsystem `RelayState`, and the seq/vec equivalence tests in
tests/test_vec_collab.py pin that.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import prototypes
from repro.relay import base, placement
from repro.relay.base import EMPTY_OWNER, SEED_OWNER, default_capacity
from repro.types import CollabConfig


class RelayState(NamedTuple):
    """Everything the flat relay holds, as fixed-shape arrays (a jax pytree).

    obs   (cap, C, d') f32 : observation ring buffer
    valid (cap, C)    bool : per-slot per-class validity
    owner (cap,)      int32: uploading client id (or SEED/EMPTY sentinel)
    ptr   ()          int32: next ring write position
    global_protos (C, d') f32, valid_g (C,) bool: the t̄^c prototypes
    mean_logits (C, C) f32 : FD-mode per-class mean logits (zeros otherwise)
    stamp (cap,)      int32: birth clock of the slot's observation (the
                             server logical clock when it was produced —
                             the event log's commit stamp, relay/events.py)
    clock ()          int32: server logical clock (merges performed)
    """
    obs: jax.Array
    valid: jax.Array
    owner: jax.Array
    ptr: jax.Array
    global_protos: jax.Array
    valid_g: jax.Array
    mean_logits: jax.Array
    stamp: jax.Array
    clock: jax.Array

    @property
    def capacity(self) -> int:
        return self.obs.shape[0]


def init_relay_state(ccfg: CollabConfig, d_feature: int, seed: int = 0,
                     capacity: Optional[int] = None,
                     n_clients: int = 2) -> RelayState:
    """Paper Algorithm 1: S initializes randomly {t̄^c} and the observation
    buffers. The random initial prototypes are load-bearing: they are a
    COMMON anchor that aligns the clients' (independently initialized)
    feature spaces in round 1, so that inter-client averaging of per-class
    means is meaningful from round 2 on. Without it, averaging across
    unaligned feature spaces cancels class structure and L_KD collapses the
    model (verified empirically; see tests)."""
    C = ccfg.num_classes
    cap = default_capacity(ccfg, n_clients) if capacity is None else capacity
    assert cap > 0, "relay buffer capacity must be positive"
    n_seed = min(cap, max(1, ccfg.m_down))
    rng = np.random.default_rng(seed)
    protos = rng.normal(size=(C, d_feature)).astype(np.float32) * 0.01
    obs = np.zeros((cap, C, d_feature), np.float32)
    obs[:n_seed] = rng.normal(size=(n_seed, C, d_feature)).astype(np.float32) * 0.01
    valid = np.zeros((cap, C), bool)
    valid[:n_seed] = True
    owner = np.full((cap,), EMPTY_OWNER, np.int32)
    owner[:n_seed] = SEED_OWNER
    return RelayState(obs=jnp.asarray(obs), valid=jnp.asarray(valid),
                      owner=jnp.asarray(owner),
                      ptr=jnp.asarray(n_seed % cap, jnp.int32),
                      global_protos=jnp.asarray(protos),
                      valid_g=jnp.ones((C,), bool),
                      mean_logits=jnp.zeros((C, C), jnp.float32),
                      stamp=jnp.zeros((cap,), jnp.int32),
                      clock=jnp.zeros((), jnp.int32))


# -- uplink (pure) ---------------------------------------------------------
def buffer_append(state: RelayState, obs_rows, valid_rows, owner_rows,
                  row_mask=None, stamp_rows=None) -> RelayState:
    """Write k observation rows into the ring (oldest-first overwrite).

    obs_rows (k, C, d'), valid_rows (k, C), owner_rows (k,) int32,
    row_mask (k,) bool or None. Rows with row_mask False are dropped
    without consuming a ring slot (absent clients in a partial round).
    stamp_rows (k,) int32 or None: per-row birth clocks (None = born at the
    current clock — the synchronous case). The number of masked-in rows
    must not exceed capacity (scatter order for duplicate ring indices is
    undefined); callers size the buffer with `default_capacity`.
    """
    k = obs_rows.shape[0]
    cap = state.obs.shape[0]
    idx, new_ptr = base.ring_indices(state.ptr, k, cap, row_mask)
    stamps = base.stamps_or_now(state, k, stamp_rows)
    return state._replace(
        obs=state.obs.at[idx].set(obs_rows.astype(jnp.float32), mode="drop"),
        valid=state.valid.at[idx].set(valid_rows, mode="drop"),
        owner=state.owner.at[idx].set(owner_rows.astype(jnp.int32),
                                      mode="drop"),
        stamp=state.stamp.at[idx].set(stamps, mode="drop"),
        ptr=new_ptr)


def merge_round(state: RelayState, proto: prototypes.ProtoState,
                logit: Optional[prototypes.ProtoState] = None) -> RelayState:
    """Inter-client aggregation (the server's only computation, Alg. 1):
    per-round recompute of t̄^c from the merged per-class sums."""
    return base.merge_protos(state, proto, logit)


def evict_slots(state, owners) -> RelayState:
    """Invalidate live slots owned by evicted clients (flat ring layout,
    shared by flat and staleness states). Ptr/clock/billing untouched."""
    hit = base.owner_hits(state.owner, owners)
    state = state._replace(
        owner=jnp.where(hit, EMPTY_OWNER, state.owner),
        valid=jnp.where(hit[:, None], False, state.valid),
        stamp=jnp.where(hit, 0, state.stamp))
    if hasattr(state, "age"):
        state = state._replace(age=jnp.where(hit, 0, state.age))
    return state


# -- downlink (pure) -------------------------------------------------------
def sample_teacher(state: RelayState, client_id, m_down: int, key) -> Dict:
    """Observations of OTHER users, chosen at random (paper §4: 'downloads
    the representations of another user chosen at random').

    Pure and jit/vmap-compatible: uniform with-replacement sampling over the
    ring slots not owned by `client_id`; falls back to the whole filled
    buffer when every slot is the client's own, and to a zero/invalid
    teacher when the buffer is entirely empty. Always returns the full
    teacher dict (all keys, fixed shapes)."""
    usable = state.owner != EMPTY_OWNER
    others = usable & (state.owner != jnp.asarray(client_id, jnp.int32))
    pool = jnp.where(jnp.any(others), others, usable)
    any_pool = jnp.any(pool)
    logits = jnp.where(pool, 0.0, -jnp.inf)
    k_sample, k_pick = jax.random.split(jnp.asarray(key))
    idx = jax.random.categorical(k_sample, logits, shape=(m_down,))
    idx = jnp.where(any_pool, idx, 0)
    obs = jnp.where(any_pool, state.obs[idx], 0.0)            # (M, C, d')
    valid_o = jnp.where(any_pool, jnp.all(state.valid[idx], axis=0), False)
    return {"global_protos": state.global_protos,
            "valid_g": state.valid_g,
            "obs": obs, "valid_o": valid_o,
            "obs_pick": jax.random.randint(k_pick, (), 0, m_down,
                                           dtype=jnp.int32),
            "mean_logits": state.mean_logits}


@dataclass(frozen=True)
class FlatRelay(base.RelayPolicy):
    """Policy wrapper over the module-level pure functions above."""
    name: str = "flat"

    def init_state(self, ccfg, d_feature, seed=0, capacity=None,
                   n_clients=2):
        return init_relay_state(ccfg, d_feature, seed, capacity, n_clients)

    def append(self, state, obs_rows, valid_rows, owner_rows, row_mask=None,
               stamp_rows=None):
        return buffer_append(state, obs_rows, valid_rows, owner_rows,
                             row_mask, stamp_rows)

    def sample_teacher(self, state, client_id, m_down, key):
        return sample_teacher(state, client_id, m_down, key)

    def merge_round(self, state, proto, logit=None):
        return merge_round(state, proto, logit)

    def evict_owners(self, state, owners):
        return evict_slots(state, owners)

    def out_spec(self, state):
        """Placement declaration (relay/placement.py): the flat ring IS the
        shared pool — any client may sample any slot and one append
        interleaves all clients' rows through one write pointer — so every
        leaf (ring, prototypes, ptr, clock) is REPLICATED."""
        return placement.like(state, placement.REPLICATED)

    def debug_entries(self, state):
        owner = np.asarray(state.owner)
        return [{"obs": state.obs[i], "valid": state.valid[i],
                 "owner": int(owner[i])}
                for i in np.where(owner != EMPTY_OWNER)[0]]
