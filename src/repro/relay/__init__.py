"""Relay-policy + participation subsystem (see relay/README.md).

Public surface:
  - policies: FlatRelay | PerClassRelay | StalenessRelay | ShardedRelay
    (cohort shards over any of the former), via `get_policy`
  - schedules: FullParticipation | UniformK | Cyclic | BernoulliP |
    AdaptiveParticipation, via `get_schedule`
  - `relay.events`: the asynchronous event-ordered commit log (pending
    uploads, event ordering, clock stamps) driven by `repro.sim` clocks
  - `relay.history`: the bounded post-merge snapshot ring for stale
    (download-lag) teacher reads, driven by `repro.sim` download clocks
  - `RelayServer`: stateful wrapper for the sequential trainer
  - base contract + sentinels in `relay.base`
"""
from __future__ import annotations

from typing import Union

from repro.relay import events, history  # noqa: F401
from repro.relay.base import (EMPTY_OWNER, SEED_OWNER, TEACHER_KEYS,
                              RelayPolicy, default_capacity)  # noqa: F401
from repro.relay.flat import FlatRelay, RelayState  # noqa: F401
from repro.relay.participation import (AdaptiveParticipation,  # noqa: F401
                                       BernoulliP, Cyclic,
                                       FullParticipation,
                                       ParticipationSchedule, UniformK,
                                       get_schedule)
from repro.relay import placement  # noqa: F401
from repro.relay.per_class import PerClassRelay, PerClassRelayState  # noqa: F401
from repro.relay.server import RelayServer  # noqa: F401
from repro.relay.shards import (ShardedRelay,  # noqa: F401
                                ShardedRelayState, shard_of, shard_view)
from repro.relay.staleness import (StalenessRelay,  # noqa: F401
                                   StalenessRelayState, staleness_weights)
from repro.specs import parse_spec

POLICIES = {"flat": FlatRelay, "per_class": PerClassRelay,
            "staleness": StalenessRelay, "sharded": ShardedRelay}


def get_policy(spec: Union[str, RelayPolicy, None], **kwargs) -> RelayPolicy:
    """Resolve a policy name ("flat" | "per_class" | "staleness", optionally
    "staleness:<lam>") or instance; None means the flat (seed) policy.
    "sharded:<inner>,<S>[,<gossip_every>]" wraps an inner policy name in S
    cohort shards (inner policies needing their own args are passed as
    instances: ShardedRelay(inner=StalenessRelay(lam=...), shards=S))."""
    if spec is None:
        return FlatRelay()
    if isinstance(spec, RelayPolicy):
        return spec
    name, args = parse_spec(spec, "relay policy", POLICIES)
    if name == "staleness" and args:
        kwargs.setdefault("lam", float(args[0]))
    if name == "sharded":
        if args:
            kwargs.setdefault("inner", get_policy(args[0]))
        if len(args) > 1:
            kwargs.setdefault("shards", int(args[1]))
        if len(args) > 2:
            kwargs.setdefault("gossip_every", int(args[2]))
    return POLICIES[name](**kwargs)
