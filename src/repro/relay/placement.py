"""Placement — where each piece of fleet state lives on a client mesh.

The relay subsystem holds three kinds of round state, and they want
different homes on a multi-device mesh:

  - relay states (flat / per_class / staleness rings, global prototypes):
    the SHARED pool every client reads and the server merges — REPLICATED;
  - the async pending buffer (relay/events.py): one in-flight slot row per
    upload position, never read across clients until commit —
    CLIENT_SHARDED over the leading client axis;
  - the download-lag history ring (relay/history.py): snapshots of a
    replicated state — REPLICATED.

Before this module, every "… on the mesh" feature needed its own engine
branch (an explicit `psum` here, an `all_gather` there), so each new
feature landed off-mesh first and raised when a mesh was present. The
redesign (ROADMAP item 1) inverts that: state classes DECLARE a placement
via an `out_spec`-style contract (`RelayPolicy.out_spec`,
`events.out_spec`, `history.out_spec`), the engine resolves declarations
to `jax.jit` in/out shardings, and GSPMD inserts the collectives. The
traced round body is identical with and without a mesh — off-mesh
bit-compatibility is structural, not re-proven per feature.

The one-exchange-per-round invariant: the only point where client-sharded
values cross devices is `exchange()` — the upload payload (observation
rows + prototype sums) is constrained to REPLICATED right before the relay
append/merge. Everything upstream (teacher sampling, local updates, upload
computation) is element-wise along the client axis; everything downstream
(append, merge, history push) is replicated. This is the placement-driven
analogue of Alpa's cross-mesh resharding: like its `broadcast` vs
`send_recv` choice, the exchange strategy is derived from declared source
and destination placements (CLIENT_SHARDED -> REPLICATED lowers to an
all-gather / psum), not hard-coded into the pipeline runtime.

`axis` defaults to the collaborative engines' "clients" mesh axis
(`sharding.client_mesh`); the LM launch path resolves the same
declarations against its "pod" axis (launch/train.py).
"""
from __future__ import annotations

import jax

from repro import sharding

# Placement of one state leaf. CLIENT_SHARDED means the LEADING axis is the
# client axis; everything else is REPLICATED.
REPLICATED = "replicated"
CLIENT_SHARDED = "client_sharded"

# The vectorized collab engines' mesh axis name (sharding.client_mesh).
CLIENT_AXIS = "clients"

_VALID = (REPLICATED, CLIENT_SHARDED)


def _check(placement: str):
    if placement not in _VALID:
        raise ValueError(
            f"unknown placement: {placement!r} (have {sorted(_VALID)})")


def like(tree, placement: str):
    """Placement pytree: `tree`'s structure with every leaf = `placement`."""
    _check(placement)
    return jax.tree.map(lambda _: placement, tree)


def device_spec(mesh, placement: str, axis: str = CLIENT_AXIS):
    """Resolve ONE placement to a NamedSharding on `mesh`."""
    _check(placement)
    if placement == CLIENT_SHARDED:
        return sharding.leading_axis(mesh, axis)
    return sharding.replicated(mesh)


def resolve(placements, mesh, axis: str = CLIENT_AXIS):
    """Resolve a placement pytree (from an `out_spec` declaration) to a
    same-structure NamedSharding pytree — what `jax.jit`'s
    in_shardings/out_shardings consume. `placements` may also be a single
    placement string (jit broadcasts a sharding prefix over the arg's
    subtree)."""
    if isinstance(placements, str):
        return device_spec(mesh, placements, axis)
    return jax.tree.map(lambda p: device_spec(mesh, p, axis), placements)


def exchange(tree, mesh, axis: str = CLIENT_AXIS):
    """THE cross-device exchange: constrain every leaf of `tree` to
    REPLICATED. Called exactly once per round, on the upload payload, right
    before the relay append/merge; GSPMD lowers the
    CLIENT_SHARDED -> REPLICATED transition to the all-gather (rows) and
    all-reduce (prototype sums) that used to be hand-written engine
    branches. No-op without a mesh, so the traced body stays identical
    off-mesh."""
    if mesh is None:
        return tree
    rep = sharding.replicated(mesh)
    return jax.tree.map(
        lambda x: jax.lax.with_sharding_constraint(x, rep), tree)
