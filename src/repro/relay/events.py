"""Event-ordered asynchronous relay log — bounded-delay uploads.

The synchronous engines assume a lockstep round barrier: every upload
produced in round r is committed to the relay in round r. Cross-device
fleets break that — a straggler's upload arrives rounds later. This module
is the event log that makes lateness CORRECT instead of impossible:

  - an upload born in round r by the client at upload position u, with
    commit delay d (from a `repro.sim.ClockModel`, d <= D_max), becomes the
    event  (birth=r, pos=u)  committed in round r + d;
  - round t commits, in EVENT ORDER, every event whose commit round is t:
    ascending birth round first (oldest in-flight upload wins the ring
    slot ordering), upload position second. Fresh delay-0 uploads have
    birth t and therefore commit LAST — they are the newest events;
  - each committed observation row is stamped with the upload's BIRTH
    clock (the server logical clock when it was produced), so clock-based
    staleness (relay/base.py) sees through the delay;
  - uploads still in flight are parked in a fixed-shape pending buffer of
    D_max slots per client, indexed by birth round mod D_max. Bounded
    delay makes this collision-free: the entry born in round r has
    committed by round r + D_max, which is exactly when the slot is needed
    again — the wraparound invariant the property tests pin.

Both engines consume the same log semantics. The vectorized engine carries
`PendingState` (arrays, everything below `init_pending` is pure and lives
inside its jitted round step); the sequential oracle replays the identical
event order through the host-side `HostEventQueue` and remains the
bit-exact ring-bookkeeping reference. `D_max = 0` holds no pending state
and commits every upload at birth — bit-identical to the synchronous
engines.

Prototype sums ride the same events: a delayed upload's per-class sums
join the round-t merge (order-free — addition commutes), so the global
prototypes of round t average exactly the contributions that COMMITTED in
round t, not the ones that were merely produced.
"""
from __future__ import annotations

from typing import Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.relay import placement


class PendingState(NamedTuple):
    """In-flight uploads of a fleet, fixed shape, indexed by
    [upload position u, pending slot j = birth round mod D_max].

    obs   (N, D, m, C, d') f32 : parked observation rows
    valid (N, D, C)   bool     : per-class validity of each parked upload
    psum  (N, D, C, d') f32    : parked per-class prototype sums
    pcnt  (N, D, C)   f32      : parked per-class prototype counts
    lsum / lcnt                : FD-mode logit-proto sums (None otherwise)
    birth (N, D) int32         : round the upload was produced in
    stamp (N, D) int32         : server logical clock at birth
    commit (N, D) int32        : round the upload is due to commit in
    live  (N, D) bool          : slot holds an in-flight upload
    """
    obs: jax.Array
    valid: jax.Array
    psum: jax.Array
    pcnt: jax.Array
    lsum: Optional[jax.Array]
    lcnt: Optional[jax.Array]
    birth: jax.Array
    stamp: jax.Array
    commit: jax.Array
    live: jax.Array

    @property
    def d_max(self) -> int:
        return self.live.shape[1]


def init_pending(n: int, d_max: int, m_up: int, num_classes: int,
                 d_feature: int, fd: bool = False) -> PendingState:
    """Empty pending buffer for n upload positions. `fd` adds the
    logit-proto fields (C x C sums)."""
    C, d = num_classes, d_feature
    z = lambda *s: jnp.zeros(s, jnp.float32)
    zi = lambda *s: jnp.zeros(s, jnp.int32)
    return PendingState(
        obs=z(n, d_max, m_up, C, d), valid=jnp.zeros((n, d_max, C), bool),
        psum=z(n, d_max, C, d), pcnt=z(n, d_max, C),
        lsum=z(n, d_max, C, C) if fd else None,
        lcnt=z(n, d_max, C) if fd else None,
        birth=zi(n, d_max), stamp=zi(n, d_max),
        commit=jnp.full((n, d_max), -1, jnp.int32),
        live=jnp.zeros((n, d_max), bool))


def out_spec(pending: PendingState):
    """Placement declaration (relay/placement.py): every pending leaf is
    indexed [upload position, pending slot, ...] and an in-flight upload is
    never read by another client until it commits, so the whole buffer is
    CLIENT_SHARDED over its leading (upload position) axis. The commit
    itself is the one exchange point — see `commit_and_park`'s `mesh`."""
    return placement.like(pending, placement.CLIENT_SHARDED)


def event_slot_order(round_idx, d_max: int):
    """Pending-slot permutation putting slots in EVENT (birth-ascending)
    order for a round-`round_idx` commit: slot of birth round_idx - d_max,
    ..., slot of birth round_idx - 1. (d_max,) int32; round_idx may be a
    traced scalar."""
    births = round_idx - jnp.arange(d_max, 0, -1, dtype=jnp.int32)
    return jnp.mod(births, d_max).astype(jnp.int32)


def _ordered(pending: PendingState, order):
    """Reorder the pending buffer to (D, N, ...) with D in event order."""
    return jax.tree.map(lambda a: jnp.moveaxis(a, 0, 1)[order], pending)


def commit_and_park(policy, rstate, pending: PendingState, fresh: Dict,
                    round_idx, delays, mask, mesh=None):
    """ONE round of the asynchronous relay, pure and jit-compatible:
    commit every due event in event order, then park this round's delayed
    uploads. The single relay write of the async engines.

    fresh: this round's uploads as per-client arrays in UPLOAD (bucket)
    order — dict(obs (N, m, C, d'), valid (N, C), psum (N, C, d'),
    pcnt (N, C), lsum/lcnt or None, owner (N,) int32 original client ids).
    round_idx () int32 traced; delays (N,) int32 (this round's commit
    delays, upload order); mask (N,) bool participation. `mesh`, when
    given, marks the assembled commit payload as THE round's cross-device
    exchange (placement.exchange): the due rows and prototype sums leave
    the client-sharded domain right before the replicated append/merge,
    and GSPMD lowers the transition to one all-gather/all-reduce.

    Returns (new_rstate, new_pending). A round with zero commits leaves
    rstate untouched (no append, no merge, no clock tick) — the async
    generalization of the zero-participant no-op round.
    """
    N = fresh["owner"].shape[0]
    m = fresh["obs"].shape[1]
    D = pending.d_max
    fresh_commit = mask & (delays == 0)
    fresh_stamp = policy.stamp_now(rstate, fresh["owner"])

    # -- gather the commit set in event order ------------------------------
    rep = lambda a: jnp.repeat(a, m, axis=0)          # upload -> m obs rows
    if D > 0:
        order = event_slot_order(round_idx, D)
        po = _ordered(pending, order)                 # (D, N, ...) pytree
        due = po.live & (po.commit == round_idx)      # (D, N)
        flat = lambda a: a.reshape((D * N,) + a.shape[2:])
        obs_rows = jnp.concatenate([
            flat(po.obs).reshape(D * N * m, *po.obs.shape[3:]),
            fresh["obs"].reshape(N * m, *fresh["obs"].shape[2:])])
        valid_rows = jnp.concatenate([rep(flat(po.valid)),
                                      rep(fresh["valid"])])
        owner_rows = jnp.concatenate([
            rep(jnp.broadcast_to(fresh["owner"][None], (D, N)).reshape(-1)),
            rep(fresh["owner"])])
        row_mask = jnp.concatenate([rep(flat(due)), rep(fresh_commit)])
        stamp_rows = jnp.concatenate([rep(flat(po.stamp)), rep(fresh_stamp)])
        wf = fresh_commit.astype(jnp.float32)
        wdue = due.astype(jnp.float32)
        any_commit = jnp.any(due) | jnp.any(fresh_commit)
    else:
        obs_rows = fresh["obs"].reshape(N * m, *fresh["obs"].shape[2:])
        valid_rows = rep(fresh["valid"])
        owner_rows = rep(fresh["owner"])
        row_mask = rep(fresh_commit)
        stamp_rows = rep(fresh_stamp)
        wf = fresh_commit.astype(jnp.float32)
        any_commit = jnp.any(fresh_commit)

    from repro.core import prototypes

    def _reduce(fsum, fcnt, parked_sum, parked_cnt):
        """Reduce this round's committing prototype sums to the policy's
        merge input. Default: mask-weighted sum over upload positions plus
        the due parked sums — EXACTLY the synchronous upload phase, so a
        round with zero pending contribution is bit-identical to the sync
        merge. Policies with `reduce_uploads` (e.g. cohort shards) segment
        the same per-position contributions by owner instead; owners are
        static per position (arrivals x async is rejected at construction),
        so a position's parked sums belong to the same owner as its fresh."""
        if policy.reduce_uploads is None:
            s = jnp.sum(fsum * wf[:, None, None], axis=0)
            c = jnp.sum(fcnt * wf[:, None], axis=0)
            if D > 0:
                s = s + jnp.einsum("dn,dn...->...", wdue, parked_sum)
                c = c + jnp.einsum("dn,dn...->...", wdue, parked_cnt)
            return prototypes.ProtoState(s, c)
        s = fsum * wf[:, None, None]
        c = fcnt * wf[:, None]
        if D > 0:
            s = s + jnp.einsum("dn,dn...->n...", wdue, parked_sum)
            c = c + jnp.einsum("dn,dn...->n...", wdue, parked_cnt)
        return policy.reduce_uploads(s, c, jnp.ones((N,), jnp.float32),
                                     fresh["owner"])

    proto = _reduce(fresh["psum"], fresh["pcnt"],
                    po.psum if D > 0 else None, po.pcnt if D > 0 else None)
    logit = None
    if fresh.get("lsum") is not None:
        logit = _reduce(fresh["lsum"], fresh["lcnt"],
                        po.lsum if D > 0 else None, po.lcnt if D > 0 else None)

    # THE cross-device exchange: the commit payload (due rows + merged
    # sums) becomes replicated here; everything above is element-wise along
    # the client axis, everything below touches only replicated state.
    (obs_rows, valid_rows, owner_rows, row_mask, stamp_rows, proto, logit,
     any_commit) = placement.exchange(
        (obs_rows, valid_rows, owner_rows, row_mask, stamp_rows, proto,
         logit, any_commit), mesh)

    new_rstate = policy.append(rstate, obs_rows, valid_rows, owner_rows,
                               row_mask, stamp_rows)
    new_rstate = policy.merge_round(new_rstate, proto, logit)
    rstate = jax.tree.map(lambda n_, o: jnp.where(any_commit, n_, o),
                          new_rstate, rstate)

    # -- park this round's delayed uploads ---------------------------------
    if D == 0:
        return rstate, pending
    park = mask & (delays > 0)                         # (N,)
    slot = jnp.mod(round_idx, D).astype(jnp.int32)     # free: see module doc
    live = pending.live & (pending.commit != round_idx)   # retire the due
    put = lambda buf, v: buf.at[:, slot].set(v)
    new_pending = pending._replace(
        obs=put(pending.obs, fresh["obs"]),
        valid=put(pending.valid, fresh["valid"]),
        psum=put(pending.psum, fresh["psum"]),
        pcnt=put(pending.pcnt, fresh["pcnt"]),
        lsum=(put(pending.lsum, fresh["lsum"])
              if pending.lsum is not None else None),
        lcnt=(put(pending.lcnt, fresh["lcnt"])
              if pending.lcnt is not None else None),
        birth=put(pending.birth, jnp.broadcast_to(round_idx, (N,))
                  .astype(jnp.int32)),
        stamp=put(pending.stamp, fresh_stamp),
        commit=put(pending.commit, (round_idx + delays).astype(jnp.int32)),
        live=put(live, park))
    return rstate, new_pending


# ---------------------------------------------------------------------------
# the sequential oracle's replay queue + host-side commit bookkeeping
# ---------------------------------------------------------------------------
class HostEventQueue:
    """Host-side event log: the sequential oracle's (and the vectorized
    engine's billing mirror's) replay of the commit order above. Events are
    (birth, pos, client_id, stamp, payload); `pop_due(t)` returns round t's
    commit set sorted by (birth, pos) — exactly the order
    `commit_and_park` appends rows in."""

    def __init__(self):
        self._events: List[Tuple[int, int, int, int, object]] = []

    def push(self, birth: int, pos: int, client_id: int, stamp: int,
             payload, delay: int):
        self._events.append((int(birth), int(pos), int(client_id),
                             int(stamp), payload, int(birth) + int(delay)))

    def pop_due(self, round_idx: int):
        due = sorted((e for e in self._events if e[5] == int(round_idx)),
                     key=lambda e: (e[0], e[1]))
        self._events = [e for e in self._events if e[5] != int(round_idx)]
        return due

    def __len__(self):
        return len(self._events)


class CommitMirror:
    """Payload-free `HostEventQueue` so the vectorized engine can report
    per-round commit lists and bill the comm ledger WITHOUT pulling device
    arrays: both engines derive the same (birth, client) commit sets from
    the same deterministic masks/delays, through the SAME queue semantics
    (one definition of the commit order, not two)."""

    def __init__(self):
        self._q = HostEventQueue()

    def step(self, round_idx: int, mask: np.ndarray, delays: np.ndarray,
             upload_order) -> List[Tuple[int, int]]:
        """Advance one round: returns the round's commits as
        [(birth_round, client_id), ...] in event order."""
        for pos, cid in enumerate(upload_order):
            if mask[cid]:
                self._q.push(birth=round_idx, pos=pos, client_id=int(cid),
                             stamp=0, payload=None,
                             delay=int(delays[cid]))
        return [(birth, cid)
                for birth, _, cid, *_ in self._q.pop_due(round_idx)]
