"""Bounded relay-state history ring — stale snapshot reads (download lag).

The synchronous engines let every client download from the relay state of
the PREVIOUS round — a round-fresh read. Real cross-device fleets don't get
that: a duty-cycled phone trains against whatever snapshot it fetched at
its last wake-up, possibly several rounds old. PR 4's event log made
*uploads* late; this module is the symmetric half for *downloads*: keep the
last `H_max` post-merge relay snapshots in a fixed-shape ring so a client
training in round t can sample its teachers and global prototypes from a
snapshot `d ≤ H_max − 1` rounds staler than its round-start sync — what
its round-`t − d` self would have read fresh, i.e. the post-merge state of
round `t − d − 1` (d = 0 is the round-start state itself).

Layout: a `History` holds one stacked pytree — every leaf of the relay
state gains a leading `(H_max,)` axis — plus a scalar `head` pointing at
the MOST RECENT snapshot. This works for all three relay policies (and any
future one obeying the base contract) because policy states are fixed-shape
NamedTuple pytrees: stacking is policy-agnostic, and `read_at` returns a
state of the original type that `sample_teacher` consumes unchanged.

The functions below `init` are pure jax (jit/vmap-compatible, no
data-dependent Python), so both engines share them:

  - the vectorized engine threads the `History` through its ONE jitted
    round step: each client's snapshot is a dynamic index into the history
    axis (`read_at` under `vmap` lowers to a batched gather that XLA fuses
    with the teacher-row gather — no per-client state copies, and `delay`
    is a traced argument so lag patterns never retrace);
  - the sequential oracle replays the same ring host-side (a bounded
    most-recent-first list in `core/collab.py`) and stays the bit-exact
    reference.

Semantics pinned by tests/test_property.py:

  - `push` evicts the oldest snapshot once the ring is full (wraparound at
    `H_max`, like the event log's pending buffer at `D_max`);
  - `read_at(hist, d)` returns EXACTLY the snapshot `d` pushes ago for
    `d ≤ H_max − 1` (never a younger one), and clamps deeper requests to
    the oldest retained snapshot (never older than `H_max − 1`);
  - every slot starts as the INITIAL state, so a read that reaches past
    the pushes performed so far sees the Algorithm-1 init state — exactly
    what a client that never synced would hold.

`H_max = 1` is the degenerate ring: the only retained snapshot is the
current post-merge state, so delay-0 reads are bit-identical to the
history-free engines (the acceptance anchor in tests/test_download_lag.py).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.relay import placement


class History(NamedTuple):
    """snaps: the stacked snapshot pytree — every leaf (H_max, ...);
    head: () int32 — ring slot of the most recent snapshot."""
    snaps: Any
    head: jax.Array

    @property
    def h_max(self) -> int:
        return jax.tree.leaves(self.snaps)[0].shape[0]


def init(snapshot, h_max: int) -> History:
    """Ring of `h_max` copies of `snapshot` (host-side; run once). Every
    slot holds the initial state so early deep reads are well-defined."""
    assert h_max >= 1, h_max
    snaps = jax.tree.map(
        lambda a: jnp.repeat(jnp.asarray(a)[None], h_max, axis=0), snapshot)
    return History(snaps=snaps, head=jnp.zeros((), jnp.int32))


def out_spec(hist: History):
    """Placement declaration (relay/placement.py): the ring stacks
    snapshots of a REPLICATED relay state along a history axis, and every
    client must be able to read any snapshot depth — the whole ring
    (snaps + head) is REPLICATED. `read_at` under a client-sharded delay
    vector is then a local gather per device, no collective."""
    return placement.like(hist, placement.REPLICATED)


def push(hist: History, snapshot) -> History:
    """Append a post-merge snapshot, evicting the oldest. Pure; called once
    per round INSIDE the engines' jitted round steps."""
    h = hist.h_max
    head = jnp.mod(hist.head + 1, h).astype(jnp.int32)
    snaps = jax.tree.map(lambda buf, a: buf.at[head].set(a),
                         hist.snaps, snapshot)
    return History(snaps=snaps, head=head)


def read_at(hist: History, delay):
    """The snapshot `delay` pushes ago (0 = most recent), clamped to the
    ring depth: requests past `H_max − 1` return the oldest retained
    snapshot. `delay` may be traced; under `vmap` this is one batched
    gather over the history axis."""
    h = hist.h_max
    d = jnp.clip(jnp.asarray(delay).astype(jnp.int32), 0, h - 1)
    slot = jnp.mod(hist.head - d, h)
    return jax.tree.map(lambda buf: buf[slot], hist.snaps)
