"""ParticipationSchedule — which clients take part in each round.

Cross-device reality: clients skip rounds (straggler devices, dropped
connections, duty-cycling). A schedule is a deterministic host-side function
`mask(round_idx, n_clients) -> (N,) bool` consumed by BOTH engines, so the
sequential oracle (which simply skips absent clients) and the vectorized
engine (which masks the stacked client axis inside its single jitted round
step) see byte-identical participation and stay equivalence-testable.

Determinism is the load-bearing property: the mask depends only on the
schedule's parameters and the round index — never on call order or hidden
RNG state — so two independently constructed trainers agree round by round.

`fixed_k` tells the vectorized engine whether the per-round participant
count is a static number: when it is (uniform_k, cyclic), the engine gathers
the k participants into a compact (k, ...) block and the round step costs
O(k) instead of O(N) — real compute savings, not just masking. Variable-
count schedules (bernoulli_p) return None and run full-width with masking.

Semantics shared by both engines:
  - absent clients neither download, update, nor upload; their params and
    Adam moments are frozen for the round;
  - the prototype merge averages over PRESENT clients only;
  - the comm ledger bills only present clients;
  - a round with zero participants leaves the relay state untouched
    (no merge, no aging) — it is a pure no-op plus an eval.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def bcast_mask(vec, like):
    """Broadcast a (k,) mask/weight vector against a (k, ...) leaf."""
    return vec.reshape(vec.shape + (1,) * (like.ndim - 1))


def freeze_absent(mask, new_tree, old_tree):
    """THE masking semantics of partial participation, in one place:
    present clients (mask True) take the freshly computed leaves, absent
    clients keep their old ones bit-for-bit. Leading axis = clients."""
    return jax.tree.map(
        lambda n, o: jnp.where(bcast_mask(mask, n), n, o),
        new_tree, old_tree)


class ParticipationSchedule:
    name: str = "abstract"

    @property
    def fixed_k(self) -> Optional[int]:
        """Static per-round participant count, or None when it varies."""
        return None

    def mask(self, round_idx: int, n_clients: int) -> np.ndarray:
        raise NotImplementedError


@dataclass(frozen=True)
class FullParticipation(ParticipationSchedule):
    """Every client, every round (the seed engines' implicit schedule)."""
    name: str = "full"

    def mask(self, round_idx: int, n_clients: int) -> np.ndarray:
        return np.ones((n_clients,), bool)


@dataclass(frozen=True)
class UniformK(ParticipationSchedule):
    """k clients drawn uniformly without replacement each round (the
    FedAvg-paper "random fraction" schedule)."""
    k: int
    seed: int = 0
    name: str = "uniform_k"

    @property
    def fixed_k(self) -> Optional[int]:
        return self.k

    def mask(self, round_idx: int, n_clients: int) -> np.ndarray:
        assert 0 < self.k <= n_clients, (self.k, n_clients)
        rng = np.random.default_rng([self.seed, round_idx])
        m = np.zeros((n_clients,), bool)
        m[rng.choice(n_clients, self.k, replace=False)] = True
        return m


@dataclass(frozen=True)
class Cyclic(ParticipationSchedule):
    """Deterministic round-robin: round r serves clients
    {(r·k + i) mod N : i < k}. Every client participates exactly k/N of the
    time with worst-case wait ceil(N/k) rounds — the duty-cycle schedule."""
    k: int
    name: str = "cyclic"

    @property
    def fixed_k(self) -> Optional[int]:
        return self.k

    def mask(self, round_idx: int, n_clients: int) -> np.ndarray:
        assert 0 < self.k <= n_clients, (self.k, n_clients)
        m = np.zeros((n_clients,), bool)
        m[(round_idx * self.k + np.arange(self.k)) % n_clients] = True
        return m


@dataclass(frozen=True)
class BernoulliP(ParticipationSchedule):
    """Each client independently present with probability p (dropout-style;
    the participant count varies round to round, possibly to zero)."""
    p: float
    seed: int = 0
    name: str = "bernoulli_p"

    def mask(self, round_idx: int, n_clients: int) -> np.ndarray:
        assert 0.0 <= self.p <= 1.0, self.p
        rng = np.random.default_rng([self.seed, round_idx])
        return rng.random(n_clients) < self.p


def get_schedule(spec, seed: int = 0) -> ParticipationSchedule:
    """Parse a CLI-style schedule spec into a schedule object.

    Specs: "full" | "uniform_k:K" | "cyclic:K" | "bernoulli:P", e.g.
    "uniform_k:8" or "bernoulli:0.5". A ParticipationSchedule instance
    passes through unchanged; None means full participation.
    """
    if spec is None:
        return FullParticipation()
    if isinstance(spec, ParticipationSchedule):
        return spec
    name, _, arg = str(spec).partition(":")
    if name == "full":
        return FullParticipation()
    if name == "uniform_k":
        return UniformK(k=int(arg), seed=seed)
    if name == "cyclic":
        return Cyclic(k=int(arg))
    if name in ("bernoulli", "bernoulli_p"):
        return BernoulliP(p=float(arg), seed=seed)
    raise ValueError(f"unknown participation schedule: {spec!r}")
