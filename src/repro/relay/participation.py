"""ParticipationSchedule — which clients take part in each round.

Cross-device reality: clients skip rounds (straggler devices, dropped
connections, duty-cycling). A schedule is a deterministic host-side function
`mask(round_idx, n_clients) -> (N,) bool` consumed by BOTH engines, so the
sequential oracle (which simply skips absent clients) and the vectorized
engine (which masks the stacked client axis inside its single jitted round
step) see byte-identical participation and stay equivalence-testable.

Determinism is the load-bearing property: the mask depends only on the
schedule's parameters and the round index — never on call order or hidden
RNG state — so two independently constructed trainers agree round by round.

`fixed_k` tells the vectorized engine whether the per-round participant
count is a static number: when it is (uniform_k, cyclic), the engine gathers
the k participants into a compact (k, ...) block and the round step costs
O(k) instead of O(N) — real compute savings, not just masking. Variable-
count schedules (bernoulli_p) return None and run full-width with masking.

Semantics shared by both engines:
  - absent clients neither download, update, nor upload; their params and
    Adam moments are frozen for the round;
  - the prototype merge averages over PRESENT clients only;
  - the comm ledger bills only present clients;
  - a round with zero participants leaves the relay state untouched
    (no merge, no aging) — it is a pure no-op plus an eval.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.specs import parse_spec


def bcast_mask(vec, like):
    """Broadcast a (k,) mask/weight vector against a (k, ...) leaf."""
    return vec.reshape(vec.shape + (1,) * (like.ndim - 1))


def freeze_absent(mask, new_tree, old_tree):
    """THE masking semantics of partial participation, in one place:
    present clients (mask True) take the freshly computed leaves, absent
    clients keep their old ones bit-for-bit. Leading axis = clients."""
    return jax.tree.map(
        lambda n, o: jnp.where(bcast_mask(mask, n), n, o),
        new_tree, old_tree)


class ParticipationSchedule:
    name: str = "abstract"

    @property
    def fixed_k(self) -> Optional[int]:
        """Static per-round participant count, or None when it varies."""
        return None

    def mask(self, round_idx: int, n_clients: int) -> np.ndarray:
        raise NotImplementedError


@dataclass(frozen=True)
class FullParticipation(ParticipationSchedule):
    """Every client, every round (the seed engines' implicit schedule)."""
    name: str = "full"

    def mask(self, round_idx: int, n_clients: int) -> np.ndarray:
        return np.ones((n_clients,), bool)


@dataclass(frozen=True)
class UniformK(ParticipationSchedule):
    """k clients drawn uniformly without replacement each round (the
    FedAvg-paper "random fraction" schedule)."""
    k: int
    seed: int = 0
    name: str = "uniform_k"

    @property
    def fixed_k(self) -> Optional[int]:
        return self.k

    def mask(self, round_idx: int, n_clients: int) -> np.ndarray:
        assert 0 < self.k <= n_clients, (self.k, n_clients)
        rng = np.random.default_rng([self.seed, round_idx])
        m = np.zeros((n_clients,), bool)
        m[rng.choice(n_clients, self.k, replace=False)] = True
        return m


@dataclass(frozen=True)
class Cyclic(ParticipationSchedule):
    """Deterministic round-robin: round r serves clients
    {(r·k + i) mod N : i < k}. Every client participates exactly k/N of the
    time with worst-case wait ceil(N/k) rounds — the duty-cycle schedule."""
    k: int
    name: str = "cyclic"

    @property
    def fixed_k(self) -> Optional[int]:
        return self.k

    def mask(self, round_idx: int, n_clients: int) -> np.ndarray:
        assert 0 < self.k <= n_clients, (self.k, n_clients)
        m = np.zeros((n_clients,), bool)
        m[(round_idx * self.k + np.arange(self.k)) % n_clients] = True
        return m


@dataclass(frozen=True)
class BernoulliP(ParticipationSchedule):
    """Each client independently present with probability p (dropout-style;
    the participant count varies round to round, possibly to zero)."""
    p: float
    seed: int = 0
    name: str = "bernoulli_p"

    def mask(self, round_idx: int, n_clients: int) -> np.ndarray:
        assert 0.0 <= self.p <= 1.0, self.p
        rng = np.random.default_rng([self.seed, round_idx])
        return rng.random(n_clients) < self.p


class AdaptiveParticipation(ParticipationSchedule):
    """Closed-loop schedule (ROADMAP "Adaptive participation"): boost a
    straggler's participation probability from its OBSERVED commit delays.

    Open-loop schedules treat every client alike, but under a straggler
    clock model a slow client's uploads commit rounds late — it effectively
    contributes less per wall-clock round. This schedule keeps a per-client
    EMA of the commit delays the server has OBSERVED (a commit born in
    round r arriving in round r+d is observed, with value d, in round r+d)
    and raises the straggler's presence probability:

        p_i(t) = clip(p · (1 + boost · ema_i(t) / (1 + D_max)), p, 1)

    so a persistent straggler is scheduled up to `(1 + boost)`× as often,
    amortizing its lateness with extra attempts, while fast clients stay
    at the base rate.

    Determinism (the property every schedule must keep): the mask depends
    only on (p, boost, seed, the bound ClockModel, round index). The
    observation stream is DERIVED, not fed: clock delays are deterministic
    and past masks are recursively determined, so two independently
    constructed instances — one per engine — agree round by round, which is
    exactly how the seq/vec equivalence tests drive it. Bind the fleet's
    clock with `bind_clock` (the trainers do); unbound, all observed
    delays are 0 and this degenerates to `bernoulli:p`.
    """
    name: str = "adaptive"

    def __init__(self, p: float = 0.5, boost: float = 1.0, seed: int = 0,
                 alpha: float = 0.3):
        from repro.relay import events
        assert 0.0 < p <= 1.0, p
        self.p, self.boost, self.seed, self.alpha = p, boost, seed, alpha
        self.clock = None
        self._masks: list = []          # per computed round: (N,) bool
        self._ema: Optional[np.ndarray] = None
        # in-flight uploads, through the SAME event-queue semantics the
        # relay commits with (relay/events.py) — the observed timeline IS
        # the commit timeline by construction, not by parallel bookkeeping
        self._inflight = events.HostEventQueue()

    def bind_clock(self, clock) -> "AdaptiveParticipation":
        """Attach the fleet's ClockModel (the source of observed delays).
        Must happen before the first `mask` call."""
        assert not self._masks, "bind_clock must precede the first mask()"
        self.clock = clock
        return self

    def _probs(self, n_clients: int) -> np.ndarray:
        if self._ema is None:
            self._ema = np.zeros((n_clients,))
        d_max = self.clock.d_max if self.clock is not None else 0
        p = self.p * (1.0 + self.boost * self._ema / (1.0 + d_max))
        return np.clip(p, self.p, 1.0)

    def mask(self, round_idx: int, n_clients: int) -> np.ndarray:
        while len(self._masks) <= round_idx:
            t = len(self._masks)
            m = (np.random.default_rng([self.seed, 0xada, t])
                 .random(n_clients) < self._probs(n_clients))
            self._masks.append(m)
            delays = (self.clock.delays(t, n_clients)
                      if self.clock is not None
                      else np.zeros(n_clients, np.int64))
            for i in np.nonzero(m)[0]:
                self._inflight.push(birth=t, pos=int(i), client_id=int(i),
                                    stamp=0, payload=int(delays[i]),
                                    delay=int(delays[i]))
            # observe this round's arrivals (incl. delay-0 births), in
            # commit (event) order
            for _, _, i, _, d, _ in self._inflight.pop_due(t):
                self._ema[i] = (1 - self.alpha) * self._ema[i] \
                    + self.alpha * d
        return self._masks[round_idx].copy()


def get_schedule(spec, seed: int = 0, clock=None) -> ParticipationSchedule:
    """Parse a CLI-style schedule spec into a schedule object.

    Specs: "full" | "uniform_k:K" | "cyclic:K" | "bernoulli:P" |
    "adaptive:P[,BOOST]", e.g. "uniform_k:8" or "adaptive:0.5,2". A
    ParticipationSchedule instance passes through unchanged; None means
    full participation. `clock` (a repro.sim.ClockModel) is bound to
    adaptive schedules — they close the loop on its observed commit delays.
    """
    if spec is None:
        return FullParticipation()
    if isinstance(spec, ParticipationSchedule):
        if isinstance(spec, AdaptiveParticipation) and clock is not None \
                and spec.clock is None:
            spec.bind_clock(clock)
        return spec
    name, args = parse_spec(
        spec, "participation schedule",
        ("full", "uniform_k", "cyclic", "bernoulli", "adaptive"),
        aliases={"bernoulli_p": "bernoulli"})
    if name == "full":
        return FullParticipation()
    if name == "uniform_k":
        return UniformK(k=int(args[0]), seed=seed)
    if name == "cyclic":
        return Cyclic(k=int(args[0]))
    if name == "bernoulli":
        return BernoulliP(p=float(args[0]), seed=seed)
    # adaptive
    sched = AdaptiveParticipation(
        p=float(args[0]) if args else 0.5,
        boost=float(args[1]) if len(args) > 1 else 1.0, seed=seed)
    return sched.bind_clock(clock) if clock is not None else sched
