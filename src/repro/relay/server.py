"""Stateful relay wrapper for the sequential `CollabTrainer` path.

`RelayServer` binds a `RelayPolicy` to a live state pytree and exposes the
upload/relay/merge cadence of paper Algorithm 1. The vectorized engine never
uses this class — it closes over the policy's pure functions inside its
jitted round step — but both paths evolve the same state because the policy
functions are shared and the call order (appends in client-id order, then
one merge) is identical.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core import prototypes
from repro.relay import base, flat
from repro.types import CollabConfig


@partial(jax.jit, static_argnums=(0, 3))
def _sample_teacher_jit(policy, state, client_id, m_down, key):
    """Module-level jit so the compile cache is shared across RelayServer
    instances: policies are frozen dataclasses (hashable, equal by fields),
    so every server with an equal policy reuses one trace."""
    return policy.sample_teacher(state, client_id, m_down, key)


class RelayServer:
    def __init__(self, ccfg: CollabConfig, d_feature: int, seed: int = 0,
                 capacity: Optional[int] = None, n_clients: int = 2,
                 policy: Optional[base.RelayPolicy] = None):
        self.ccfg = ccfg
        self.d = d_feature
        self.policy = policy if policy is not None else flat.FlatRelay()
        self.state = self.policy.init_state(ccfg, d_feature, seed, capacity,
                                            n_clients)
        self.round_states: List[prototypes.ProtoState] = []
        self.round_logit_states: List[prototypes.ProtoState] = []
        self.round_owners: List[int] = []

    # -- uplink ------------------------------------------------------------
    def begin_round(self):
        self.round_states = []
        self.round_logit_states = []
        self.round_owners = []

    def upload(self, client_id: int, payload: Dict, stamp=None):
        """Append one client's upload. `stamp` (int or None) is the birth
        clock of the upload — the server logical clock when it was
        PRODUCED. None means born now (the synchronous case); the async
        event log (relay/events.py) passes the true birth clock so delayed
        commits arrive correctly pre-aged."""
        self.round_states.append(payload["proto"])
        self.round_owners.append(int(client_id))
        if "logit_proto" in payload:
            self.round_logit_states.append(payload["logit_proto"])
        obs = payload["obs"]                                  # (M_up, C, d')
        m = obs.shape[0]
        self.state = self.policy.append(
            self.state, obs,
            jnp.broadcast_to(payload["valid"], (m,) + payload["valid"].shape),
            jnp.full((m,), client_id, jnp.int32),
            stamp_rows=(None if stamp is None
                        else jnp.full((m,), stamp, jnp.int32)))

    def end_round(self):
        if not self.round_states:
            return
        if self.policy.reduce_uploads is None:
            merged = prototypes.merge(*self.round_states)
            logit = (prototypes.merge(*self.round_logit_states)
                     if self.round_logit_states else None)
        else:
            # Policy-owned reduction (e.g. per-shard partial sums): stack
            # the per-upload contributions and let the policy segment them
            # by owner. Weights are 1 — every staged upload commits.
            owners = jnp.asarray(self.round_owners, jnp.int32)
            w = jnp.ones((len(self.round_owners),), jnp.float32)
            merged = self.policy.reduce_uploads(
                jnp.stack([p.sum for p in self.round_states]),
                jnp.stack([p.count for p in self.round_states]), w, owners)
            logit = (self.policy.reduce_uploads(
                jnp.stack([p.sum for p in self.round_logit_states]),
                jnp.stack([p.count for p in self.round_logit_states]),
                w, owners) if self.round_logit_states else None)
        self.state = self.policy.merge_round(self.state, merged, logit)

    # -- downlink ----------------------------------------------------------
    def relay(self, client_id: int, m_down: int, key, state=None) -> Dict:
        """Sample a teacher for `client_id`. `state` (default: the live
        state) lets the download-lag oracle read from a HISTORICAL
        snapshot (core/collab.py keeps the host-side ring of post-merge
        states, mirroring relay/history.py): snapshots share the live
        state's shapes, so the jitted sampler never retraces."""
        return _sample_teacher_jit(self.policy,
                                   self.state if state is None else state,
                                   jnp.asarray(client_id, jnp.int32),
                                   m_down, key)

    # -- introspection (tests / notebooks) ---------------------------------
    @property
    def global_protos(self) -> jax.Array:
        return self.state.global_protos

    @property
    def valid_g(self) -> jax.Array:
        return self.state.valid_g

    @property
    def mean_logits(self) -> jax.Array:
        return self.state.mean_logits

    @property
    def obs_buffer(self) -> List[Dict]:
        """Filled slots as a list of entry dicts (compat view; every entry
        carries an "owner" key, including seeded/fallback entries)."""
        return self.policy.debug_entries(self.state)
