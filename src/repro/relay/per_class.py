"""Per-class ring relay — the paper's exact buffer layout (§4, Alg. 1).

The paper's server keeps one observation buffer PER CLASS ("S stores the
received observations in the corresponding class buffers"), not one flat
ring: a class a client uploads often cannot evict other classes' history.
State is (C, cap_c, d') with per-class-slot validity/owner/age and one write
pointer per class; the downlink samples m_down slots per class independently
(uniform over other clients' valid slots in that class's ring).

The flat ring conflates retention across classes — under label-skewed
partitions a majority class overwrites minority-class observations. The
per-class layout is the fix, and `age` (rounds since the slot was written,
maintained by `merge_round`) is recorded per slot so retention studies and
the staleness policy's sampling math share one bookkeeping scheme.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.relay import base, placement
from repro.relay.base import EMPTY_OWNER, SEED_OWNER
from repro.types import CollabConfig


class PerClassRelayState(NamedTuple):
    """obs (C, cap_c, d') f32; valid/age/stamp (C, cap_c); owner (C, cap_c)
    int32; ptr (C,) int32 — one independent ring per class — plus the shared
    prototype/clock fields (see relay/base.py). `stamp` is each slot's birth
    clock and `age` is always clock − stamp for valid slots (recomputed in
    `merge_round`), 0 for empty ones."""
    obs: jax.Array
    valid: jax.Array
    owner: jax.Array
    age: jax.Array
    ptr: jax.Array
    global_protos: jax.Array
    valid_g: jax.Array
    mean_logits: jax.Array
    stamp: jax.Array
    clock: jax.Array

    @property
    def capacity(self) -> int:
        """Per-class slot count cap_c."""
        return self.obs.shape[1]


@dataclass(frozen=True)
class PerClassRelay(base.RelayPolicy):
    name: str = "per_class"

    def init_state(self, ccfg: CollabConfig, d_feature: int, seed: int = 0,
                   capacity: Optional[int] = None,
                   n_clients: int = 2) -> PerClassRelayState:
        """Same Algorithm-1 init as the flat ring (random common-anchor
        prototypes + seeded observations), per class. `capacity` is the
        per-class slot count cap_c; the default matches the flat ring's
        slot count, so total storage (slots × C rows) is identical."""
        C = ccfg.num_classes
        cap_c = (base.default_capacity(ccfg, n_clients) if capacity is None
                 else capacity)
        assert cap_c > 0, "per-class relay capacity must be positive"
        n_seed = min(cap_c, max(1, ccfg.m_down))
        rng = np.random.default_rng(seed)
        protos = rng.normal(size=(C, d_feature)).astype(np.float32) * 0.01
        obs = np.zeros((C, cap_c, d_feature), np.float32)
        obs[:, :n_seed] = rng.normal(
            size=(C, n_seed, d_feature)).astype(np.float32) * 0.01
        valid = np.zeros((C, cap_c), bool)
        valid[:, :n_seed] = True
        owner = np.full((C, cap_c), EMPTY_OWNER, np.int32)
        owner[:, :n_seed] = SEED_OWNER
        return PerClassRelayState(
            obs=jnp.asarray(obs), valid=jnp.asarray(valid),
            owner=jnp.asarray(owner),
            age=jnp.zeros((C, cap_c), jnp.int32),
            ptr=jnp.full((C,), n_seed % cap_c, jnp.int32),
            global_protos=jnp.asarray(protos),
            valid_g=jnp.ones((C,), bool),
            mean_logits=jnp.zeros((C, C), jnp.float32),
            stamp=jnp.zeros((C, cap_c), jnp.int32),
            clock=jnp.zeros((), jnp.int32))

    # -- uplink (pure) -----------------------------------------------------
    def append(self, state: PerClassRelayState, obs_rows, valid_rows,
               owner_rows, row_mask=None,
               stamp_rows=None) -> PerClassRelayState:
        """Scatter k uploaded rows into their class rings.

        obs_rows (k, C, d'), valid_rows (k, C), owner_rows (k,),
        row_mask (k,) bool or None, stamp_rows (k,) int32 or None (birth
        clocks; None = born at the current clock). Row i contributes its
        class-c slice to ring c only when valid_rows[i, c] (the client had
        samples of class c) and row_mask[i]; each ring's pointer advances
        by its own write count. Per class, writes land in row order —
        identical to appending the rows one by one — so the sequential
        oracle (one append per client) and the vectorized engine (one
        batched append) evolve the same rings. Masked-in writes per class
        must not exceed cap_c."""
        k, C = valid_rows.shape
        cap_c = state.obs.shape[1]
        if row_mask is None:
            row_mask = jnp.ones((k,), bool)
        stamps = base.stamps_or_now(state, k, stamp_rows)
        w = valid_rows & row_mask[:, None]                     # (k, C)
        offs = jnp.cumsum(w.astype(jnp.int32), axis=0) - 1
        slot = jnp.where(w, (state.ptr[None, :] + offs) % cap_c,
                         cap_c).astype(jnp.int32)              # (k, C)
        cidx = jnp.broadcast_to(jnp.arange(C, dtype=jnp.int32)[None], (k, C))
        owner_b = jnp.broadcast_to(owner_rows.astype(jnp.int32)[:, None],
                                   (k, C))
        stamp_b = jnp.broadcast_to(stamps[:, None], (k, C))
        return state._replace(
            obs=state.obs.at[cidx, slot].set(
                obs_rows.astype(jnp.float32), mode="drop"),
            valid=state.valid.at[cidx, slot].set(True, mode="drop"),
            owner=state.owner.at[cidx, slot].set(owner_b, mode="drop"),
            age=state.age.at[cidx, slot].set(state.clock - stamp_b,
                                             mode="drop"),
            stamp=state.stamp.at[cidx, slot].set(stamp_b, mode="drop"),
            ptr=(state.ptr + jnp.sum(w.astype(jnp.int32), axis=0)) % cap_c)

    # -- downlink (pure) ---------------------------------------------------
    def sample_teacher(self, state: PerClassRelayState, client_id,
                       m_down: int, key) -> Dict:
        """Per-class uniform sampling over OTHER clients' valid slots.

        For each class c independently: sample m_down slots from ring c's
        pool (others' valid slots; falls back to all valid slots when every
        one is the requester's own, and to a zero/invalid teacher row for
        classes whose ring is empty). Teacher obs[m, c] = ring_c[slot]."""
        C, cap_c = state.valid.shape
        usable = state.valid                                    # (C, cap_c)
        others = usable & (state.owner != jnp.asarray(client_id, jnp.int32))
        pool = jnp.where(jnp.any(others, axis=1, keepdims=True), others,
                         usable)
        any_pool = jnp.any(pool, axis=1)                        # (C,)
        # uniform over the pool; empty classes get a uniform dummy row so
        # categorical stays well-defined, then the gather is zeroed out.
        logits = jnp.where(pool, 0.0, -jnp.inf)
        logits = jnp.where(any_pool[:, None], logits, 0.0)
        k_sample, k_pick = jax.random.split(jnp.asarray(key))
        idx = jax.random.categorical(k_sample, logits,
                                     shape=(m_down, C))         # (M, C)
        obs = state.obs[jnp.arange(C, dtype=jnp.int32)[None, :], idx]
        obs = jnp.where(any_pool[None, :, None], obs, 0.0)      # (M, C, d')
        return {"global_protos": state.global_protos,
                "valid_g": state.valid_g,
                "obs": obs, "valid_o": any_pool,
                "obs_pick": jax.random.randint(k_pick, (), 0, m_down,
                                               dtype=jnp.int32),
                "mean_logits": state.mean_logits}

    def merge_round(self, state, proto, logit=None):
        """Prototype merge + clock tick; age recomputed from the stamps
        (see relay/base.py's clock contract)."""
        state = base.merge_protos(state, proto, logit)
        return state._replace(age=jnp.where(state.valid,
                                            state.clock - state.stamp,
                                            state.age))

    def evict_owners(self, state, owners):
        hit = base.owner_hits(state.owner, owners)   # (C, cap_c)
        return state._replace(
            owner=jnp.where(hit, EMPTY_OWNER, state.owner),
            valid=jnp.where(hit, False, state.valid),
            age=jnp.where(hit, 0, state.age),
            stamp=jnp.where(hit, 0, state.stamp))

    def out_spec(self, state):
        """Placement declaration (relay/placement.py): the leading axis of
        every ring leaf is the CLASS axis (C independent rings shared by
        all clients), not a client axis — the whole state is REPLICATED."""
        return placement.like(state, placement.REPLICATED)

    def debug_entries(self, state):
        valid = np.asarray(state.valid)
        owner = np.asarray(state.owner)
        return [{"obs": state.obs[c, s], "class": int(c),
                 "valid": bool(valid[c, s]), "owner": int(owner[c, s]),
                 "age": int(np.asarray(state.age)[c, s])}
                for c, s in zip(*np.where(valid))]
