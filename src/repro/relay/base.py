"""RelayPolicy — the pluggable server-side sampling/retention API.

The paper's server is a *relay*: it never touches model weights, so the only
server-side design freedom is (a) how observations are retained and (b) how a
teacher is sampled for a downloading client. A `RelayPolicy` packages exactly
those two choices behind four functions; everything else (local updates,
uploads, accounting) is policy-agnostic and lives in the engines.

Contract — every method except `init_state` must be a pure jax function of
fixed-shape arrays (jit/vmap/shard_map-compatible, no data-dependent Python):

  init_state(ccfg, d_feature, seed, capacity, n_clients) -> state pytree
      Host-side (numpy ok). Seeds the buffers and random prototypes
      (Algorithm 1 init — the common anchor that aligns feature spaces).
  append(state, obs_rows, valid_rows, owner_rows, row_mask=None,
         stamp_rows=None) -> state
      Write k uploaded observation rows. `row_mask` (k,) bool, when given,
      drops masked rows WITHOUT consuming ring slots (partial participation:
      absent clients' fixed-shape rows must not advance the write pointer).
      `stamp_rows` (k,) int32, when given, are the rows' BIRTH clocks (the
      server logical clock when each upload was produced — see the clock
      contract below); None stamps every row with the current clock, i.e.
      the synchronous "born now" case.
  sample_teacher(state, client_id, m_down, key) -> teacher dict
      The downlink. Must return the full fixed-shape teacher dict (keys
      `TEACHER_KEYS`) regardless of buffer fill state.
  merge_round(state, proto, logit=None) -> state
      End-of-round aggregation of the clients' per-class sums into global
      prototypes (the server's only computation), plus any per-round state
      bookkeeping (e.g. staleness age increments).
  evict_owners(state, owners) -> state
      Population bookkeeping: invalidate every live slot whose owner is in
      `owners` ((E,) int32; pad with EMPTY_OWNER, which never matches).
      Slots become EMPTY (owner=EMPTY_OWNER, valid=False, stamp/age reset)
      but the write pointer and clock are untouched — eviction frees
      retention space without rewinding history or billing. Engines call it
      at round START for clients the cohort table (repro.sim.population)
      LRU-evicted, in BOTH engines, so it is part of the oracle contract.

Two optional hooks support policies whose state is not a single ring:

  reduce_uploads(psum, pcnt, w, owners) -> proto pytree   [default: None]
      When not None, engines route the per-upload prototype contributions
      (leading axis = uploads; `w` (k,) f32 commit weights, `owners` (k,)
      int32) through the policy instead of the builtin mask-weighted sum,
      and pass the result as `merge_round`'s `proto`/`logit`. The sharded
      relay uses this to keep per-shard partial sums. None (the default)
      keeps the engines' existing reduction byte-identical.
  stamp_now(state, owners) -> (k,) int32
      Birth stamps for uploads born "now" by the given owners. Default:
      broadcast of the scalar state clock (same program as before the hook
      existed); the sharded relay stamps each owner with its shard clock.
      `host_stamps` is the host-side mirror the sequential oracle uses.

Ordering: engines call `append` (phase 3 uploads, event order — commit
order; client-id/bucket order for synchronous fleets) and THEN
`merge_round`, exactly once per round. Policies may rely on that order (the
staleness policy does: fresh slots are written at age 0, then aged by the
merge, so a slot uploaded r rounds ago has age r).

Clock contract: every state carries a server logical clock (`clock`, ()
int32 — the number of merges performed) and a per-slot `stamp` (the birth
clock of the observation occupying the slot). `merge_round` ticks the
clock; a round with no commits calls neither `append` nor `merge_round`,
so the clock freezes with the rest of the state. Slot age is a CLOCK
property — `age = clock - stamp` for live slots — not a counter: policies
that expose an `age` field recompute it from the stamps in `merge_round`,
which makes a delayed upload (stamped with its birth clock by the async
event log, repro.relay.events) arrive correctly pre-aged. For synchronous
fleets (every row born at the current clock) this is bit-identical to the
old once-per-round increment.

Policies are small frozen dataclasses so they can be closed over by jitted
round steps and used as dict keys. States are NamedTuple pytrees. Every state
carries the shared prototype fields (`global_protos`, `valid_g`,
`mean_logits`); `merge_protos` below implements that common part (including
the clock tick).

Snapshot contract: because states are fixed-shape array pytrees with no
hidden host state, any policy's state can be stacked along a leading
history axis and read back by dynamic index — that is all
`repro.relay.history` (the download-lag snapshot ring) assumes, so every
policy obeying this contract supports stale snapshot reads for free:
`sample_teacher` runs unchanged on a `history.read_at` snapshot, and the
ages it sees are the snapshot's own `clock − stamp` (a client reading a
stale state sees the world exactly as it was at that clock).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import prototypes
from repro.relay import placement
from repro.types import CollabConfig

# Ring-slot owner sentinels. Real clients are >= 0.
SEED_OWNER = -1      # server-seeded random observation (paper Alg. 1 init)
EMPTY_OWNER = -2     # slot never written

# Fixed teacher-dict schema (what client_lib.loss_fn consumes); every policy
# returns exactly these keys with the same shapes/dtypes.
TEACHER_KEYS = ("global_protos", "valid_g", "obs", "valid_o", "obs_pick",
                "mean_logits")


def default_capacity(ccfg: CollabConfig, n_clients: int = 2) -> int:
    """Mirror the old list-server bound: 32 · N · M_↑ live observations."""
    return 32 * max(1, n_clients) * max(1, ccfg.m_up)


def merge_protos(state, proto: prototypes.ProtoState,
                 logit: Optional[prototypes.ProtoState] = None):
    """Shared part of `merge_round`: per-round recompute of t̄^c (Alg. 1)
    plus the server logical-clock tick (one tick per merge)."""
    state = state._replace(global_protos=prototypes.means(proto),
                           valid_g=proto.count > 0,
                           clock=state.clock + 1)
    if logit is not None:
        state = state._replace(mean_logits=prototypes.means(logit))
    return state


def stamps_or_now(state, k: int, stamp_rows=None):
    """Resolve `append`'s stamp_rows default: rows born at the current
    clock. (k,) int32."""
    if stamp_rows is None:
        return jnp.full((k,), state.clock, jnp.int32)
    return stamp_rows.astype(jnp.int32)


class RelayPolicy:
    """Abstract base; see module docstring for the contract."""
    name: str = "abstract"

    def init_state(self, ccfg: CollabConfig, d_feature: int, seed: int = 0,
                   capacity: Optional[int] = None, n_clients: int = 2):
        raise NotImplementedError

    def append(self, state, obs_rows, valid_rows, owner_rows, row_mask=None,
               stamp_rows=None):
        raise NotImplementedError

    def sample_teacher(self, state, client_id, m_down: int, key) -> Dict:
        raise NotImplementedError

    def merge_round(self, state, proto, logit=None):
        raise NotImplementedError

    def evict_owners(self, state, owners):
        raise NotImplementedError

    # -- optional engine hooks (see module docstring) ----------------------
    # When None, engines keep their builtin mask-weighted proto reduction
    # (byte-identical programs for every pre-existing policy).
    reduce_uploads = None

    def stamp_now(self, state, owners):
        """Birth stamps for uploads born at the current clock. Default:
        broadcast of the scalar clock (identical ops to the pre-hook
        inline code)."""
        return jnp.broadcast_to(state.clock.astype(jnp.int32),
                                owners.shape)

    def host_stamps(self, state, owners) -> np.ndarray:
        """Host-side mirror of `stamp_now` for the sequential oracle:
        numpy int stamps for uploads born now by `owners` (host ints)."""
        return np.full((len(owners),), int(np.asarray(state.clock)),
                       dtype=np.int64)

    # -- placement contract (relay/placement.py) ---------------------------
    def out_spec(self, state):
        """Placement pytree of `state` (same structure, one
        REPLICATED/CLIENT_SHARDED tag per leaf), consumed by the vectorized
        engine to resolve jit in/out shardings on a client mesh. The relay
        is the paper's SHARED pool — every client samples from it and the
        server merges into it — so the default (and every built-in
        policy's) declaration is all-REPLICATED; policies adding
        per-client-resident state override this per field."""
        return placement.like(state, placement.REPLICATED)

    # -- introspection (tests / notebooks; host-side, not traced) ----------
    def debug_entries(self, state):
        """Filled slots as a list of {"obs", "valid", "owner"} dicts."""
        raise NotImplementedError


def ring_indices(ptr, k: int, cap: int, row_mask=None):
    """Ring write positions for k rows, of which only `row_mask` are real
    (None = all). The single source of truth for flat-ring append math —
    every flat-layout policy (flat, staleness) derives its writes from it.

    Masked-out rows get index `cap` (out of bounds — scatter with
    mode="drop" discards them) and do NOT consume a slot, so the ring
    evolves exactly as if only the masked-in rows had been appended, in
    order. Returns (idx (k,) int32, new_ptr () int32).
    """
    if row_mask is None:
        idx = (ptr + jnp.arange(k, dtype=jnp.int32)) % cap
        return idx, ((ptr + k) % cap)
    w = row_mask.astype(jnp.int32)
    offs = jnp.cumsum(w) - 1                       # slot offset per real row
    idx = jnp.where(row_mask, (ptr + offs) % cap, cap).astype(jnp.int32)
    return idx, ((ptr + jnp.sum(w)) % cap).astype(jnp.int32)


def owner_hits(owner, owners):
    """Slots whose owner appears in `owners` ((E,) int32). Broadcasts over
    any owner-array shape. EMPTY_OWNER padding in `owners` only re-matches
    already-empty slots, so eviction with padded vectors is idempotent;
    SEED_OWNER never appears in an eviction list."""
    return jnp.any(owner[..., None] == owners, axis=-1)
