"""Staleness-weighted relay — age-decayed sampling over the flat ring.

In a cross-device deployment with partial participation, ring slots can be
many rounds old; a representation uploaded 50 rounds ago was produced by a
model that no longer exists, and uniform sampling keeps relaying it. This
policy tracks per-slot age and samples teachers with probability
∝ exp(-λ·age) over the eligible pool.

Age is a CLOCK property, not a counter: every slot stores the birth clock
of its observation (`stamp`) and `merge_round` recomputes
`age = clock − stamp` for live slots from the server logical clock (see
relay/base.py). For synchronous fleets every row is born at the current
clock, which is bit-identical to the old "+1 per merge, reset on write"
bookkeeping; under the asynchronous event log (relay/events.py) a delayed
upload arrives stamped with its TRUE birth clock and therefore correctly
pre-aged — exp(-λ·age) then discounts in-flight lateness for free.

Sampling is a jittable Gumbel-top-k: add i.i.d. Gumbel noise to the masked
log-weights (-λ·age over the pool, -inf outside) and take the top m_down
scores — an exact draw of m_down slots WITHOUT replacement from the
exp(-λ·age) distribution (Gumbel-max trick), with no rejection loop and no
data-dependent shapes. λ=0 recovers uniform-without-replacement over the
pool; large λ degenerates to "freshest slots only".
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.relay import base, flat, placement
from repro.relay.base import EMPTY_OWNER
from repro.types import CollabConfig


class StalenessRelayState(NamedTuple):
    """Flat ring (see relay/flat.py) + per-slot age (cap,) int32 (always
    equal to clock − stamp for live slots, 0 for empty ones — stored so
    sampling reads it directly and tests can pin it)."""
    obs: jax.Array
    valid: jax.Array
    owner: jax.Array
    age: jax.Array
    ptr: jax.Array
    global_protos: jax.Array
    valid_g: jax.Array
    mean_logits: jax.Array
    stamp: jax.Array
    clock: jax.Array

    @property
    def capacity(self) -> int:
        return self.obs.shape[0]


def staleness_logweights(age, pool, lam: float):
    """Masked log-weights: -λ·age over the pool, -inf outside. (cap,) f32."""
    return jnp.where(pool, -lam * age.astype(jnp.float32), -jnp.inf)


def staleness_weights(age, pool, lam: float):
    """Normalized sampling distribution over the ring slots: softmax of the
    masked log-weights. Sums to 1 whenever the pool is non-empty; zero on
    slots outside the pool. Exposed for the property tests."""
    return jax.nn.softmax(staleness_logweights(age, pool, lam))


@dataclass(frozen=True)
class StalenessRelay(base.RelayPolicy):
    lam: float = 0.5
    name: str = "staleness"

    def init_state(self, ccfg: CollabConfig, d_feature: int, seed: int = 0,
                   capacity: Optional[int] = None,
                   n_clients: int = 2) -> StalenessRelayState:
        """Flat-ring init + age 0 everywhere (seed slots count as fresh)."""
        s = flat.init_relay_state(ccfg, d_feature, seed, capacity, n_clients)
        return StalenessRelayState(
            obs=s.obs, valid=s.valid, owner=s.owner,
            age=jnp.zeros((s.obs.shape[0],), jnp.int32), ptr=s.ptr,
            global_protos=s.global_protos, valid_g=s.valid_g,
            mean_logits=s.mean_logits, stamp=s.stamp, clock=s.clock)

    # -- uplink (pure) -----------------------------------------------------
    def append(self, state: StalenessRelayState, obs_rows, valid_rows,
               owner_rows, row_mask=None,
               stamp_rows=None) -> StalenessRelayState:
        """Flat ring append (delegated, so the masked-index math lives in
        one place); written slots start at age = clock − birth stamp (0 for
        rows born this round, > 0 for delayed async commits)."""
        idx, _ = base.ring_indices(state.ptr, obs_rows.shape[0],
                                   state.obs.shape[0], row_mask)
        stamps = base.stamps_or_now(state, obs_rows.shape[0], stamp_rows)
        state = flat.buffer_append(state, obs_rows, valid_rows, owner_rows,
                                   row_mask, stamp_rows)
        return state._replace(
            age=state.age.at[idx].set(state.clock - stamps, mode="drop"))

    # -- downlink (pure) ---------------------------------------------------
    def sample_teacher(self, state: StalenessRelayState, client_id,
                       m_down: int, key) -> Dict:
        """Gumbel-top-k draw of m_down slots ∝ exp(-λ·age), excluding the
        requester's own uploads (same pool/fallback rules as the flat
        policy). Draws are without replacement up to the pool size; when
        the pool (or the ring itself) is smaller than m_down, the in-pool
        picks are recycled round-robin instead of poisoning the teacher
        with out-of-pool slots — matching the flat policy's tolerance of
        any m_down. Bit-identical to a plain top-k when pool >= m_down."""
        cap = state.owner.shape[0]
        usable = state.owner != EMPTY_OWNER
        others = usable & (state.owner != jnp.asarray(client_id, jnp.int32))
        pool = jnp.where(jnp.any(others), others, usable)
        any_pool = jnp.any(pool)
        logw = staleness_logweights(state.age, pool, self.lam)
        k_sample, k_pick = jax.random.split(jnp.asarray(key))
        gumbel = jax.random.gumbel(k_sample, logw.shape)
        kk = min(m_down, cap)
        _, idx_k = jax.lax.top_k(logw + gumbel, kk)   # descending score:
        # in-pool picks (finite scores) sort before out-of-pool (-inf) ones
        p = jnp.sum(pool.astype(jnp.int32))
        take = (jnp.arange(m_down, dtype=jnp.int32)
                % jnp.maximum(jnp.minimum(p, kk), 1))
        idx = jnp.where(any_pool, idx_k[take], 0)
        obs = jnp.where(any_pool, state.obs[idx], 0.0)         # (M, C, d')
        valid_o = jnp.where(any_pool,
                            jnp.all(state.valid[idx] & pool[idx, None],
                                    axis=0), False)
        return {"global_protos": state.global_protos,
                "valid_g": state.valid_g,
                "obs": obs, "valid_o": valid_o,
                "obs_pick": jax.random.randint(k_pick, (), 0, m_down,
                                               dtype=jnp.int32),
                "mean_logits": state.mean_logits}

    def merge_round(self, state, proto, logit=None):
        """Prototype merge + clock tick; age recomputed from the stamps
        (clock − birth) for live slots — the clock-based replacement of the
        old once-per-round increment (bit-identical for synchronous rows)."""
        state = base.merge_protos(state, proto, logit)
        live = state.owner != EMPTY_OWNER
        return state._replace(
            age=jnp.where(live, state.clock - state.stamp, state.age))

    def evict_owners(self, state, owners):
        return flat.evict_slots(state, owners)   # also resets age (shared)

    def out_spec(self, state):
        """Placement declaration (relay/placement.py): same shared flat
        ring as FlatRelay — the per-slot `age` column is indexed by ring
        slot, not by client — so every leaf is REPLICATED."""
        return placement.like(state, placement.REPLICATED)

    def debug_entries(self, state):
        import numpy as np
        owner = np.asarray(state.owner)
        age = np.asarray(state.age)
        return [{"obs": state.obs[i], "valid": state.valid[i],
                 "owner": int(owner[i]), "age": int(age[i])}
                for i in np.where(owner != EMPTY_OWNER)[0]]
