"""Cohort-sharded relay — S independent relays + periodic prototype gossip.

The paper's scalability claim is that relay cost depends on the buffer
capacity `cap` and the participants-per-round `k`, never on the population
N. One global ring breaks that at population scale: every append scans one
write pointer, every sampler contends for one pool, and capacity has to
grow with the population to keep owner diversity. The fix is the standard
serving-infra move: **shard by client**. Each client hashes to one of S
relay shards (`shard_of` — a pure integer mix, so a client's shard never
changes while it is active, or ever); each shard is a COMPLETE inner
`RelayPolicy` state, so flat / per_class / staleness all work unchanged.

Layout. `ShardedRelayState.shards` is the inner policy's state with every
leaf stacked along a leading (S,) axis — exactly the snapshot contract
relay/base.py already guarantees (fixed-shape NamedTuple pytrees stack
along leading axes; that is what the download-lag history ring relies on),
which is why `jax.vmap` over the shard axis runs the inner policy's pure
functions per shard with zero changes to them. Delegating properties
(`ptr`, `owner`, `clock`, ... — each (S, ...)-stacked) keep the oracle
assertions and telemetry reductions shape-generic.

Per-shard clocks. Each shard keeps its own logical clock and only ticks it
on rounds where ITS cohort committed: a shard whose cohort fully departed
is a relay no-op (the zero-participant contract from the participation
work, applied per shard) — no merge, no aging, no clock tick. Uploads are
therefore stamped with their OWNER's shard clock (`stamp_now` /
`host_stamps`), keeping `age = clock − stamp` a within-shard quantity.

Gossip. Every `gossip_every`-th merge (counted by the global `merges`
counter, which advances only on rounds that commit), the shards exchange
prototypes: the per-class weighted mean of THIS round's per-shard sums,
Σ_s sum_s / max(Σ_s cnt_s, 1) — the cheap O(C·d') merge the per-class
layout was chosen for. Empty shards contribute zero weight (no 0/0 NaN),
inactive shards do not receive (they are frozen, see above), and classes
with zero global mass fall back to each shard's own merge. With S=1 the
gossip mean IS the single-relay merge, which makes `sharded:<inner>,1`
bit-identical to the unsharded policy — the compatibility anchor the
equivalence tests pin.

Engine coupling happens through the two optional base hooks:
`reduce_uploads` segments the per-upload prototype contributions into
per-shard partial sums (so `merge_round` receives a ProtoState with
leading (S,) leaves), and `stamp_now` stamps each upload with its shard's
clock. Eviction (`evict_owners`, driven by the streaming cohort table in
repro.sim.population) is vmapped straight onto the inner policy.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import prototypes
from repro.relay import base, flat, placement
from repro.types import CollabConfig


def shard_of(client_id, n_shards: int):
    """Deterministic shard assignment: a 32-bit integer mix (murmur-style
    avalanche) mod S. Pure function of the id — a client's shard is stable
    for its whole lifetime, across sessions, engines and restarts."""
    x = jnp.asarray(client_id).astype(jnp.uint32)
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return (x % jnp.uint32(max(1, n_shards))).astype(jnp.int32)


class ShardedRelayState(NamedTuple):
    """Inner policy state stacked along a leading (S,) shard axis, plus a
    global merge counter (the gossip cadence clock). The properties expose
    the stacked inner leaves so shape-generic consumers (oracle asserts,
    telemetry, history snapshots) see the familiar field names."""
    shards: Any               # inner state; every leaf (S, ...)
    merges: jax.Array         # () int32: merges performed (any shard)

    # -- delegating views over the stacked inner state ---------------------
    @property
    def obs(self):
        return self.shards.obs

    @property
    def valid(self):
        return self.shards.valid

    @property
    def owner(self):
        return self.shards.owner

    @property
    def ptr(self):
        return self.shards.ptr

    @property
    def global_protos(self):
        return self.shards.global_protos

    @property
    def valid_g(self):
        return self.shards.valid_g

    @property
    def mean_logits(self):
        return self.shards.mean_logits

    @property
    def stamp(self):
        return self.shards.stamp

    @property
    def clock(self):
        return self.shards.clock          # (S,) per-shard clocks

    @property
    def age(self):
        return self.shards.age            # AttributeError when inner has none

    @property
    def n_shards(self) -> int:
        return self.shards.owner.shape[0]


def shard_view(state: ShardedRelayState, s):
    """One shard's inner state ((s) may be traced — a dynamic gather)."""
    return jax.tree.map(lambda leaf: leaf[s], state.shards)


@dataclass(frozen=True)
class ShardedRelay(base.RelayPolicy):
    """S inner relays + hash routing + periodic prototype gossip."""
    inner: base.RelayPolicy = field(default_factory=flat.FlatRelay)
    shards: int = 1
    gossip_every: int = 1
    name: str = "sharded"

    def __post_init__(self):
        if self.shards < 1:
            raise ValueError("sharded relay needs at least one shard")
        if self.gossip_every < 1:
            raise ValueError("gossip_every must be >= 1")
        if isinstance(self.inner, ShardedRelay):
            raise ValueError("sharded relay cannot nest another sharded relay")

    # -- contract ----------------------------------------------------------
    def init_state(self, ccfg: CollabConfig, d_feature: int, seed: int = 0,
                   capacity: Optional[int] = None,
                   n_clients: int = 2) -> ShardedRelayState:
        """Every shard starts from the SAME Algorithm-1 init: the random
        initial prototypes are the common anchor that aligns feature
        spaces, and sharing it across shards keeps cross-shard gossip
        meaningful from the first exchange. `capacity` is PER SHARD (the
        default sizes by the bounded cohort, never the population)."""
        one = self.inner.init_state(ccfg, d_feature, seed, capacity,
                                    n_clients)
        stacked = jax.tree.map(
            lambda leaf: jnp.stack([leaf] * self.shards), one)
        return ShardedRelayState(shards=stacked,
                                 merges=jnp.zeros((), jnp.int32))

    def append(self, state, obs_rows, valid_rows, owner_rows, row_mask=None,
               stamp_rows=None):
        k = owner_rows.shape[0]
        if row_mask is None:
            row_mask = jnp.ones((k,), bool)
        row_shard = shard_of(owner_rows, self.shards)            # (k,)

        def one(shard_state, s):
            return self.inner.append(shard_state, obs_rows, valid_rows,
                                     owner_rows, row_mask & (row_shard == s),
                                     stamp_rows)

        new = jax.vmap(one)(state.shards,
                            jnp.arange(self.shards, dtype=jnp.int32))
        return state._replace(shards=new)

    def sample_teacher(self, state, client_id, m_down: int, key):
        """Downlink = the client's OWN shard only (that is the scaling
        point: a download touches cap-per-shard slots, not S·cap)."""
        s = shard_of(client_id, self.shards)
        return self.inner.sample_teacher(shard_view(state, s), client_id,
                                         m_down, key)

    def reduce_uploads(self, psum, pcnt, w, owners):
        """Per-shard partial sums: ProtoState with (S, C, ...) / (S, C)
        leaves. S=1 reproduces the engines' builtin mask-weighted sum
        op-for-op (the bit-compatibility anchor)."""
        if self.shards == 1:
            wf = w.reshape((-1,) + (1,) * (psum.ndim - 1))
            return prototypes.ProtoState(
                jnp.sum(psum * wf, axis=0)[None],
                jnp.sum(pcnt * w[:, None], axis=0)[None])
        oh = (shard_of(owners, self.shards)[:, None]
              == jnp.arange(self.shards, dtype=jnp.int32)[None, :])
        wsh = w[:, None] * oh.astype(w.dtype)                    # (k, S)
        return prototypes.ProtoState(
            jnp.einsum("ks,kcd->scd", wsh, psum.astype(jnp.float32)),
            jnp.einsum("ks,kc->sc", wsh, pcnt.astype(jnp.float32)))

    def merge_round(self, state, proto, logit=None):
        """Per-shard merge with a per-shard no-op guarantee, then periodic
        gossip. `proto`/`logit` carry leading (S,) axes (reduce_uploads).

        A shard is ACTIVE this round iff it received any prototype mass;
        inactive shards (cohort departed, or simply quiet) are frozen leaf
        for leaf — no prototype recompute, no aging, no clock tick — the
        zero-participant contract applied per shard. Gossip replaces the
        active shards' prototypes with the cross-shard per-class weighted
        mean of this round's sums; empty shards contribute zero weight, so
        a 0/0 NaN cannot arise, and zero-mass classes fall back to the
        shard's own merge."""
        S = self.shards
        active = jnp.sum(proto.count, axis=tuple(range(1, proto.count.ndim)),
                         ) > 0                                    # (S,)
        if logit is None:
            merged = jax.vmap(lambda st, p: self.inner.merge_round(st, p))(
                state.shards, proto)
        else:
            merged = jax.vmap(self.inner.merge_round)(state.shards, proto,
                                                      logit)
        do_gossip = (state.merges + 1) % self.gossip_every == 0
        apply = active & do_gossip                                # (S,)
        gcnt = jnp.sum(proto.count, axis=0)                       # (C,)
        gmean = jnp.sum(proto.sum, axis=0) / jnp.maximum(gcnt, 1.0)[:, None]
        merged = merged._replace(
            global_protos=jnp.where(
                apply[:, None, None] & (gcnt > 0)[None, :, None],
                gmean[None], merged.global_protos),
            valid_g=jnp.where(apply[:, None], (gcnt > 0)[None],
                              merged.valid_g))
        if logit is not None:
            lcnt = jnp.sum(logit.count, axis=0)
            lmean = (jnp.sum(logit.sum, axis=0)
                     / jnp.maximum(lcnt, 1.0)[:, None])
            merged = merged._replace(mean_logits=jnp.where(
                apply[:, None, None] & (lcnt > 0)[None, :, None],
                lmean[None], merged.mean_logits))
        keep = jax.tree.map(
            lambda new, old: jnp.where(
                active.reshape((S,) + (1,) * (new.ndim - 1)), new, old),
            merged, state.shards)
        return ShardedRelayState(shards=keep, merges=state.merges + 1)

    def evict_owners(self, state, owners):
        """LRU-evicted owners leave every shard (their rows only ever lived
        in their hash shard; elsewhere this is a no-op match)."""
        new = jax.vmap(lambda st: self.inner.evict_owners(st, owners))(
            state.shards)
        return state._replace(shards=new)

    # -- clock plumbing (per-shard clocks; see module docstring) -----------
    def stamp_now(self, state, owners):
        return state.clock[shard_of(owners, self.shards)].astype(jnp.int32)

    def host_stamps(self, state, owners) -> np.ndarray:
        clocks = np.asarray(state.clock)
        s = np.asarray(shard_of(np.asarray(owners, np.int32), self.shards))
        return clocks[s].astype(np.int64)

    # -- placement / introspection -----------------------------------------
    def out_spec(self, state):
        """The shard axis is a STATE axis, not a client axis: every client
        must reach its own shard for downloads and the merge walks all
        shards, so the whole stacked state is REPLICATED (sharding it over
        a client mesh would put most clients' shard on a remote device)."""
        return placement.like(state, placement.REPLICATED)

    def debug_entries(self, state):
        out = []
        for s in range(self.shards):
            view = jax.tree.map(lambda leaf: leaf[s], state.shards)
            for e in self.inner.debug_entries(view):
                out.append({**e, "shard": s})
        return out
