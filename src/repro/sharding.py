"""Sharding rules: map model/activation tensors onto the mesh axes.

Mesh axes (see launch/mesh.py):
  - "pod"   : CoRS client axis (multi-pod mesh only). No gradient sync here.
  - "data"  : batch / FSDP axis.
  - "model" : tensor-parallel axis (heads / d_ff / vocab / experts).

All helpers degrade gracefully: a dimension is only sharded when divisible by
the axis size, otherwise left replicated (GSPMD would fail to partition
non-divisible dims cleanly; we keep the dry-run deterministic instead).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Version-compat shard_map: `jax.shard_map` is the stable name on newer jax;
# older releases only ship it under jax.experimental. Import it from here
# (tests and core/vec_collab.py do) so the rest of the codebase is
# version-agnostic.
try:
    shard_map = jax.shard_map
except AttributeError:                              # jax < 0.6
    from jax.experimental.shard_map import shard_map  # type: ignore


def client_mesh(n_devices: Optional[int] = None) -> Mesh:
    """1-D device mesh with a "clients" axis for the vectorized collab
    engine (vec_collab.py): the stacked client axis is sharded over it and
    the prototype merge becomes a psum. Defaults to all local devices."""
    n = n_devices or len(jax.devices())
    return jax.make_mesh((n,), ("clients",))


def axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def dp_axes(mesh: Mesh) -> tuple:
    """Axes over which the batch is sharded ("pod" folds into batch)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def dp_size(mesh: Mesh) -> int:
    n = 1
    for a in dp_axes(mesh):
        n *= mesh.shape[a]
    return n


def maybe(axis: Optional[str], dim: int, size: int):
    """Return `axis` if `dim` is divisible by `size`, else None."""
    return axis if (axis is not None and size > 1 and dim % size == 0) else None


def batch_spec(mesh: Mesh, batch: int, *rest) -> P:
    """Shard the leading batch dim over (pod, data) as far as divisible."""
    axes = []
    for a in dp_axes(mesh):
        if batch % (mesh.shape[a] * _prod(mesh, axes)) == 0:
            axes.append(a)
    lead = tuple(axes) if axes else None
    return P(lead, *rest)


def _prod(mesh: Mesh, axes: Sequence[str]) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def head_axis_plan(num_heads: int, head_dim: int, tp: int) -> str:
    """Which per-head axis the model axis shards: 'heads' | 'head_dim' | 'none'."""
    if tp <= 1:
        return "none"
    if num_heads % tp == 0:
        return "heads"
    if head_dim % tp == 0:
        return "head_dim"
    return "none"


def shard(mesh: Mesh, x, spec: P):
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def replicated(mesh: Mesh) -> NamedSharding:
    """Every-device-holds-it sharding (GSPMD P())."""
    return NamedSharding(mesh, P())


def leading_axis(mesh: Mesh, axis: str) -> NamedSharding:
    """Leading-dim-over-`axis` sharding (GSPMD P(axis)); the client axis of
    the collaborative engines ("clients" on a `client_mesh`, "pod" on the
    LM launch mesh). GSPMD pads non-divisible leading dims, so uneven
    client counts (hetero buckets) shard without a divisibility assert."""
    return NamedSharding(mesh, P(axis))


# ---------------------------------------------------------------------------
# Parameter partition rules
# ---------------------------------------------------------------------------
def param_spec(path: str, shape: tuple, mesh: Mesh, *, fsdp: bool) -> P:
    """Heuristic parameter sharding from the param-tree path.

    Conventions used by the model code (nn/ + models/):
      - 'embed'               : (vocab, d_model)        -> vocab over model
      - 'lm_head' / 'w_out'   : (d_model, vocab)        -> vocab over model
      - 'wq','wk','wv'        : (d_model, heads*hd)     -> out dim over model
      - 'wo'                  : (heads*hd, d_model)     -> in dim over model
      - 'w_gate','w_up'       : (d_model, d_ff)         -> d_ff over model
      - 'w_down'              : (d_ff, d_model)         -> d_ff over model
      - experts '..._e'       : (E, d, f)               -> f over model
      - everything else       : replicated (biases, norms, small projs)
    FSDP additionally shards the *other* matrix dim over data when divisible.
    """
    tp = axis_size(mesh, "model")
    dp = axis_size(mesh, "data")
    leaf = path.split("/")[-1]
    ndim = len(shape)
    spec = [None] * ndim

    model_dim = None  # index sharded by "model"
    if ndim >= 2:
        if leaf in ("embed", "proto"):
            model_dim = 0
        elif leaf in ("lm_head", "w_out"):
            model_dim = ndim - 1
        elif leaf in ("wq", "wk", "wv", "w_gate", "w_up", "wkv_b", "wq_b",
                      "w_in", "w_qkv"):
            model_dim = ndim - 1
        elif leaf in ("wo", "w_down"):
            model_dim = ndim - 2
        elif leaf.endswith("_e"):      # stacked expert weights (E, d, f)
            # "tp": shard the per-expert ffn dim; "ep": shard the expert dim
            model_dim = 0 if _HINTS.get("moe_ep") else ndim - 1

    if model_dim is not None and maybe("model", shape[model_dim], tp):
        spec[model_dim] = "model"

    if fsdp and ndim >= 2:
        # shard one remaining large dim over data
        for d in range(ndim - 1, -1, -1):
            if spec[d] is None and shape[d] % dp == 0 and shape[d] >= dp:
                spec[d] = "data"
                break
    return P(*spec)


def tree_param_specs(params, mesh: Mesh, *, fsdp: bool):
    """PartitionSpec pytree matching `params` (dict-of-dict pytree)."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    specs = {}
    for kp, leaf in flat:
        path = "/".join(_key_str(k) for k in kp)
        specs[path] = param_spec(path, leaf.shape, mesh, fsdp=fsdp)
    # rebuild tree
    def build(subtree, prefix):
        if isinstance(subtree, dict):
            return {k: build(v, prefix + [_plain(k)]) for k, v in subtree.items()}
        return specs["/".join(prefix)]
    return build(params, [])


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def _plain(k) -> str:
    return str(k)


# ---------------------------------------------------------------------------
# Sharding hints: knobs the launcher sets before lowering so deep layers
# (e.g. the MoE dispatch buffers) can apply mesh-aware constraints without
# threading the mesh through every call signature. Used by §Perf variants.
# ---------------------------------------------------------------------------
_HINTS = {"mesh": None, "moe_ep": False}


def set_hints(**kw):
    _HINTS.update(kw)


def hint(name: str):
    return _HINTS.get(name)


def constrain(x, *spec):
    """with_sharding_constraint against the hinted mesh (no-op without)."""
    m = _HINTS.get("mesh")
    if m is None:
        return x
    cleaned = []
    for s in spec:
        if s is not None and isinstance(s, str) and s not in m.axis_names:
            cleaned.append(None)
        else:
            cleaned.append(s)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(m, P(*cleaned)))
