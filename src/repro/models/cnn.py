"""LeNet5-style CNN — the paper's own MNIST model (≈30K params, d'=84).

f_u = τ_u ∘ φ_u: `features` returns the d'-dim last-hidden representation
(the thing CoRS shares); `classify` is the linear head τ_u. A `wide` variant
(ResNet9-ish capacity stand-in, still cheap on CPU) exercises the paper's
"larger model" regime for the benchmarks.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.nn import layers


def init_cnn(key, *, num_classes: int = 10, d_feature: int = 84,
             in_ch: int = 1, width: int = 1, image: int = 28):
    ks = layers.split(key, 6)
    c1, c2 = 6 * width, 16 * width
    # image -> conv5 -> pool2 -> conv5 -> pool2
    s1 = (image - 4) // 2
    s2 = (s1 - 4) // 2
    flat = c2 * s2 * s2
    conv = lambda k, ci, co: (jax.random.normal(k, (5, 5, ci, co))
                              * math.sqrt(2.0 / (25 * ci))).astype(jnp.float32)
    return {
        "conv1": conv(ks[0], in_ch, c1), "b1": jnp.zeros((c1,)),
        "conv2": conv(ks[1], c1, c2), "b2": jnp.zeros((c2,)),
        "fc1": layers.dense_init(ks[2], flat, 120 * width, jnp.float32),
        "fb1": jnp.zeros((120 * width,)),
        "fc2": layers.dense_init(ks[3], 120 * width, d_feature, jnp.float32),
        "fb2": jnp.zeros((d_feature,)),
        # τ_u — the linear classifier (W_u, b_u) of the paper
        "head_w": layers.dense_init(ks[4], d_feature, num_classes, jnp.float32),
        "head_b": jnp.zeros((num_classes,)),
    }


def _conv(x, w, b):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return jax.nn.relu(y + b[None, None, None, :])


def _pool(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def features(params, x):
    """φ_u: x (B, H, W, C) -> s (B, d').

    The feature layer is tanh (as in LeNet5's F6): CoRS shares and regresses
    onto these representations (L_KD), and a bounded feature space keeps
    ‖s − t̄‖² well-scaled at the paper's λ_KD = 10 — with unbounded ReLU
    features the KD pull dominates CE and collapses training (see
    EXPERIMENTS.md §Paper-claims notes)."""
    h = _pool(_conv(x, params["conv1"], params["b1"]))
    h = _pool(_conv(h, params["conv2"], params["b2"]))
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ params["fc1"] + params["fb1"])
    h = jnp.tanh(h @ params["fc2"] + params["fb2"])
    return h


def classify(params, s):
    """τ_u: s (B, d') -> logits (B, C)."""
    return s @ params["head_w"] + params["head_b"]


def apply(params, x):
    s = features(params, x)
    return s, classify(params, s)


def num_params(params) -> int:
    return sum(int(p.size) for p in jax.tree.leaves(params))
