"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

The mel-spectrogram + conv feature extractor is the allowed stub:
`encoder_frames` arrive as precomputed (B, T_enc, d_model) embeddings.
Encoder: bidirectional attention + GELU MLP. Decoder: causal self-attention
(KV-cached) + cross-attention over the encoder output (cross-KV computed once
at prefill) + GELU MLP. LayerNorm, learned-style sinusoidal positions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import blocks as _blocks
from repro.nn import attention, layers


def _sinusoid(seq: int, d: int, dtype):
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    i = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (2 * i / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def _init_enc_layer(key, cfg, dt):
    ks = layers.split(key, 2)
    return {
        "norm1": layers.init_layernorm(cfg.d_model, dt),
        "attn": attention.init_gqa(ks[0], cfg.d_model, cfg.num_heads,
                                   cfg.num_kv_heads, cfg.head_dim, dt),
        "norm2": layers.init_layernorm(cfg.d_model, dt),
        "mlp": layers.init_gelu_mlp(ks[1], cfg.d_model, cfg.d_ff, dt),
    }


def _init_dec_layer(key, cfg, dt):
    ks = layers.split(key, 3)
    return {
        "norm1": layers.init_layernorm(cfg.d_model, dt),
        "self_attn": attention.init_gqa(ks[0], cfg.d_model, cfg.num_heads,
                                        cfg.num_kv_heads, cfg.head_dim, dt),
        "norm2": layers.init_layernorm(cfg.d_model, dt),
        "cross_attn": attention.init_gqa(ks[1], cfg.d_model, cfg.num_heads,
                                         cfg.num_kv_heads, cfg.head_dim, dt),
        "norm3": layers.init_layernorm(cfg.d_model, dt),
        "mlp": layers.init_gelu_mlp(ks[2], cfg.d_model, cfg.d_ff, dt),
    }


def init_encdec(key, cfg):
    dt = jnp.dtype(cfg.dtype)
    ks = layers.split(key, 4 + cfg.num_encoder_layers + cfg.num_layers)
    enc = [_init_enc_layer(k, cfg, dt) for k in ks[:cfg.num_encoder_layers]]
    dec = [_init_dec_layer(k, cfg, dt)
           for k in ks[cfg.num_encoder_layers:
                       cfg.num_encoder_layers + cfg.num_layers]]
    stack = lambda ps: jax.tree.map(lambda *xs: jnp.stack(xs), *ps)
    return {
        "embed": layers.embed_init(ks[-1], cfg.vocab_size, cfg.d_model, dt),
        "enc_layers": stack(enc),
        "dec_layers": stack(dec),
        "enc_norm": layers.init_layernorm(cfg.d_model, dt),
        "dec_norm": layers.init_layernorm(cfg.d_model, dt),
        "lm_head": layers.dense_init(ks[-2], cfg.d_model, cfg.vocab_size, dt),
    }


_ATT_KW = dict(rope_kind="none", rope_theta=10000.0)


def encode(params, cfg, frames):
    """frames (B, T_enc, d_model) stub embeddings -> (B, T_enc, d)."""
    dt = jnp.dtype(cfg.dtype)
    B, T, _ = frames.shape
    x = frames.astype(dt) + _sinusoid(T, cfg.d_model, dt)[None]
    kw = dict(num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
              head_dim=cfg.head_dim, **_ATT_KW)
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))

    def body(x, lp):
        h = layers.layernorm(lp["norm1"], x, cfg.norm_eps)
        x = x + attention.gqa_block(lp["attn"], h, pos, causal=False, **kw)
        h = layers.layernorm(lp["norm2"], x, cfg.norm_eps)
        x = x + layers.gelu_mlp(lp["mlp"], h)
        return x, None

    x, _ = jax.lax.scan(body, x, params["enc_layers"],
                        unroll=True if _blocks.UNROLL else 1)
    return layers.layernorm(params["enc_norm"], x, cfg.norm_eps)


def _cross_kv(lp, cfg, enc_out):
    """Precompute cross-attention K/V from the encoder output."""
    B, T, _ = enc_out.shape
    k = jnp.einsum("bsd,de->bse", enc_out, lp["cross_attn"]["wk"]).reshape(
        B, T, cfg.num_kv_heads, cfg.head_dim)
    v = jnp.einsum("bsd,de->bse", enc_out, lp["cross_attn"]["wv"]).reshape(
        B, T, cfg.num_kv_heads, cfg.head_dim)
    return k, v


def decode_forward(params, cfg, tokens, enc_out, *, mode: str = "train",
                   self_cache=None, cross_kv=None, positions=None):
    """Decoder over target tokens.

    train/prefill: tokens (B, S). decode: tokens (B, 1) with self_cache
    (stacked (L,B,Sc,G,hd) pair) and cross_kv precomputed.
    Returns dict(features, logits, caches).
    """
    dt = jnp.dtype(cfg.dtype)
    B, S = tokens.shape
    kw = dict(num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
              head_dim=cfg.head_dim, **_ATT_KW)
    x = jnp.take(params["embed"], tokens, axis=0)
    if positions is None:
        offset = 0 if mode != "decode" else _self_len(self_cache) - 1
        positions = offset + jnp.arange(S, dtype=jnp.int32)[None]
        positions = jnp.broadcast_to(positions, (B, S))
    x = x + _sinusoid_at(positions, cfg.d_model, dt)

    if mode == "decode":
        def body(x, inp):
            lp, (ck, cv), (xk, xv) = inp
            h = layers.layernorm(lp["norm1"], x, cfg.norm_eps)
            y, nk, nv = attention.gqa_decode(lp["self_attn"], h, ck, cv,
                                             positions, **kw)
            x = x + y
            h = layers.layernorm(lp["norm2"], x, cfg.norm_eps)
            x = x + attention.gqa_block(lp["cross_attn"], h, positions,
                                        causal=False, kv=(xk, xv), **kw)
            h = layers.layernorm(lp["norm3"], x, cfg.norm_eps)
            x = x + layers.gelu_mlp(lp["mlp"], h)
            return x, (nk, nv)

        x, new_cache = jax.lax.scan(
            body, x, (params["dec_layers"], self_cache, cross_kv),
            unroll=True if _blocks.UNROLL else 1)
        caches = {"self": new_cache, "cross": cross_kv}
    else:
        def body(x, lp):
            h = layers.layernorm(lp["norm1"], x, cfg.norm_eps)
            if mode == "prefill":
                y, kv = attention.gqa_block(lp["self_attn"], h, positions,
                                            causal=True, return_kv=True, **kw)
            else:
                y = attention.gqa_block(lp["self_attn"], h, positions,
                                        causal=True, **kw)
                kv = (jnp.zeros((), dt),) * 2
            x = x + y
            xkv = _cross_kv(lp, cfg, enc_out)
            h = layers.layernorm(lp["norm2"], x, cfg.norm_eps)
            x = x + attention.gqa_block(lp["cross_attn"], h, positions,
                                        causal=False, kv=xkv, **kw)
            h = layers.layernorm(lp["norm3"], x, cfg.norm_eps)
            x = x + layers.gelu_mlp(lp["mlp"], h)
            return x, (kv, xkv) if mode == "prefill" else None

        x, ys = jax.lax.scan(body, x, params["dec_layers"],
                             unroll=True if _blocks.UNROLL else 1)
        caches = None
        if mode == "prefill":
            caches = {"self": ys[0], "cross": ys[1]}

    features = layers.layernorm(params["dec_norm"], x, cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", features, params["lm_head"])
    return {"features": features, "logits": logits, "caches": caches,
            "aux": jnp.zeros((), jnp.float32)}


def _sinusoid_at(positions, d, dtype):
    i = jnp.arange(d // 2, dtype=jnp.float32)[None, None, :]
    ang = positions.astype(jnp.float32)[..., None] / (10000.0 ** (2 * i / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def _self_len(self_cache) -> int:
    return self_cache[0].shape[2]


def init_self_cache(cfg, batch_size: int, ctx_len: int):
    dt = jnp.dtype(cfg.dtype)
    L = cfg.num_layers
    z = lambda hd: jnp.zeros((L, batch_size, ctx_len, cfg.num_kv_heads, hd), dt)
    return (z(cfg.head_dim), z(cfg.v_head_dim))
