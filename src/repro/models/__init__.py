from repro.models import cnn, encdec, lm  # noqa: F401
