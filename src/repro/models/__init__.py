from repro.models import cnn, encdec, lm, mlp  # noqa: F401
