"""Per-layer blocks (pre-norm residual) + segment grouping for scan.

A model is a list of *segments*: consecutive layers of the same kind, with
params stacked on a leading (L_seg,) axis and iterated by lax.scan — this
keeps the HLO size O(#kinds), not O(#layers), which is what makes the 95-layer
deepseek-67b dry-run compile tractable. Hybrid patterns (zamba2's shared
attention block every k mamba layers) interleave non-scanned shared blocks
between segments.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.nn import attention, layers, mla, moe, ssm, xlstm

# When True, layer scans fully unroll (used by launch/roofline.py depth
# variants: XLA's cost analysis counts while-loop bodies once, so roofline
# probes compile shallow unrolled models).
UNROLL = False

# Activation-checkpoint policy for the per-layer remat in training scans:
#   "full" — recompute everything in backward (min live memory, max traffic)
#   "dots" — save matmul outputs (jax dots_with_no_batch_dims_saveable)
#   "none" — no remat (max live memory, min recompute)
# §Perf knob (launch/dryrun.py --remat).
REMAT_POLICY = "full"


def _wrap_remat(fn):
    if REMAT_POLICY == "none":
        return fn
    if REMAT_POLICY == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# single-layer init / apply
# ---------------------------------------------------------------------------
def init_block(key, cfg, kind: str, dtype):
    ks = layers.split(key, 4)
    p: Dict[str, Any] = {"norm1": layers.init_norm(cfg.norm_kind, cfg.d_model, dtype)}
    if kind == "attn":
        if cfg.is_mla:
            p["attn"] = mla.init_mla(ks[0], cfg, dtype)
        else:
            p["attn"] = attention.init_gqa(ks[0], cfg.d_model, cfg.num_heads,
                                           cfg.num_kv_heads, cfg.head_dim, dtype)
        p["norm2"] = layers.init_norm(cfg.norm_kind, cfg.d_model, dtype)
        if cfg.num_experts:
            p["moe"] = moe.init_moe(ks[1], cfg.d_model, cfg.num_experts,
                                    cfg.moe_d_ff, cfg.num_shared_experts, dtype)
        else:
            p["mlp"] = layers.init_mlp(cfg.mlp_kind, ks[1], cfg.d_model,
                                       cfg.d_ff, dtype)
    elif kind == "mamba":
        p["mamba"] = ssm.init_mamba2(ks[0], cfg, dtype)
    elif kind == "mlstm":
        p["mlstm"] = xlstm.init_mlstm(ks[0], cfg, dtype)
    elif kind == "slstm":
        p["slstm"] = xlstm.init_slstm(ks[0], cfg, dtype)
    else:
        raise ValueError(kind)
    return p


def apply_block(p, cfg, kind: str, x, positions, *, window: int = 0,
                mode: str = "train", cache=None, cache_index=None,
                masked: bool = False):
    """mode: train | prefill | decode. Returns (x, aux, new_cache)."""
    aux = jnp.zeros((), jnp.float32)
    h = layers.apply_norm(cfg.norm_kind, p["norm1"], x, cfg.norm_eps)
    new_cache = None
    if kind == "attn":
        if cfg.is_mla:
            if mode == "decode":
                y, new_cache = mla.mla_decode(p["attn"], cfg, h, cache,
                                              positions,
                                              cache_index=cache_index,
                                              masked=masked)
            elif mode == "prefill":
                y, new_cache = mla.mla_block(p["attn"], cfg, h, positions,
                                             window=window, return_cache=True)
            else:
                y = mla.mla_block(p["attn"], cfg, h, positions, window=window)
        else:
            kw = dict(num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                      head_dim=cfg.head_dim, rope_kind=cfg.rope_kind,
                      rope_theta=cfg.rope_theta)
            if mode == "decode":
                ck, cv = cache
                y, nk, nv = attention.gqa_decode(
                    p["attn"], h, ck, cv, positions,
                    cache_index=cache_index, window=window, masked=masked,
                    **kw)
                new_cache = (nk, nv)
            elif mode == "prefill":
                y, new_cache = attention.gqa_block(
                    p["attn"], h, positions, causal=True, window=window,
                    return_kv=True, **kw)
            else:
                y = attention.gqa_block(p["attn"], h, positions, causal=True,
                                        window=window, **kw)
        x = x + y
        h2 = layers.apply_norm(cfg.norm_kind, p["norm2"], x, cfg.norm_eps)
        if cfg.num_experts:
            y2, aux = moe.moe_block(p["moe"], h2, num_experts=cfg.num_experts,
                                    k=cfg.experts_per_token,
                                    cf=cfg.capacity_factor,
                                    num_shared=cfg.num_shared_experts)
        else:
            y2 = layers.apply_mlp(cfg.mlp_kind, p["mlp"], h2)
        x = x + y2
    elif kind == "mamba":
        if mode == "decode":
            y, new_cache = ssm.mamba2_decode(p["mamba"], cfg, h, cache)
        elif mode == "prefill":
            y, new_cache = ssm.mamba2_block(p["mamba"], cfg, h, return_cache=True)
        else:
            y = ssm.mamba2_block(p["mamba"], cfg, h)
        x = x + y
    elif kind == "mlstm":
        if mode == "decode":
            y, new_cache = xlstm.mlstm_block(p["mlstm"], cfg, h, cache=cache,
                                             decode=True)
        elif mode == "prefill":
            y, new_cache = xlstm.mlstm_block(p["mlstm"], cfg, h,
                                             return_cache=True)
        else:
            y = xlstm.mlstm_block(p["mlstm"], cfg, h)
        x = x + y
    elif kind == "slstm":
        if mode == "decode":
            y, new_cache = xlstm.slstm_block(p["slstm"], cfg, h, cache=cache,
                                             decode=True)
        elif mode == "prefill":
            y, new_cache = xlstm.slstm_block(p["slstm"], cfg, h,
                                             return_cache=True)
        else:
            y = xlstm.slstm_block(p["slstm"], cfg, h)
        x = x + y
    else:
        raise ValueError(kind)
    return x, aux, new_cache


# ---------------------------------------------------------------------------
# segments
# ---------------------------------------------------------------------------
def segments_of(cfg) -> List[Tuple[str, int]]:
    """[(kind, n_layers), ...] grouping consecutive same-kind layers,
    additionally split at shared-attention insertion points (zamba2)."""
    segs: List[Tuple[str, int]] = []
    for i, kind in enumerate(cfg.block_pattern):
        boundary = (cfg.shared_attn_period
                    and i > 0 and i % cfg.shared_attn_period == 0)
        if segs and segs[-1][0] == kind and not boundary:
            segs[-1] = (kind, segs[-1][1] + 1)
        else:
            segs.append((kind, 1))
    return segs


def init_segments(key, cfg, dtype):
    """-> list of (kind, stacked_params) following segments_of(cfg)."""
    segs = segments_of(cfg)
    out = []
    keys = layers.split(key, len(segs))
    for (kind, n), k in zip(segs, keys):
        layer_keys = layers.split(k, n)
        ps = [init_block(lk, cfg, kind, dtype) for lk in layer_keys]
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *ps)
        out.append({"kind": kind, "params": stacked, "n": n})
    return out


def run_segment(seg_params, cfg, kind: str, x, positions, *, window: int,
                mode: str, cache=None, cache_index=None, remat: bool = True,
                masked: bool = False):
    """Scan a stacked segment. cache is stacked on the leading layer axis.
    Returns (x, aux_sum, new_cache_stacked)."""

    def body(carry, inp):
        xc = carry
        lp, lc = inp
        fn = lambda xx: apply_block(lp, cfg, kind, xx, positions,
                                    window=window, mode=mode, cache=lc,
                                    cache_index=cache_index, masked=masked)
        if remat and mode == "train":
            fn = _wrap_remat(fn)
        x2, aux, nc = fn(xc)
        return x2, (aux, nc)

    x, (auxs, new_cache) = jax.lax.scan(body, x, (seg_params, cache),
                                        unroll=True if UNROLL else 1)
    return x, jnp.sum(auxs), new_cache
