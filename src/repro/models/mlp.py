"""Small MLP client — the cheap-compute counterpart of models/cnn.py.

Same f_u = τ_u ∘ φ_u contract as the CNN (features returns the d'-dim
representation CoRS shares; tanh-bounded for the same λ_KD-scaling reason —
see cnn.features). Being all-matmul it vmaps over a stacked client axis with
near-perfect efficiency, which makes it the right instrument for measuring
ENGINE overhead (benchmarks/scaling_clients.py): with the LeNet, conv FLOPs
saturate a small CPU in both engines and hide the dispatch savings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn import layers


def init_mlp(key, *, num_classes: int = 10, d_feature: int = 84,
             d_in: int = 784, hidden: int = 64):
    ks = layers.split(key, 3)
    return {
        "w1": layers.dense_init(ks[0], d_in, hidden, jnp.float32),
        "b1": jnp.zeros((hidden,)),
        "w2": layers.dense_init(ks[1], hidden, d_feature, jnp.float32),
        "b2": jnp.zeros((d_feature,)),
        # τ_u — the linear classifier (W_u, b_u) of the paper
        "head_w": layers.dense_init(ks[2], d_feature, num_classes,
                                    jnp.float32),
        "head_b": jnp.zeros((num_classes,)),
    }


def features(params, x):
    """φ_u: x (B, ...) flattened -> s (B, d')."""
    h = x.reshape(x.shape[0], -1)
    h = jax.nn.relu(h @ params["w1"] + params["b1"])
    return jnp.tanh(h @ params["w2"] + params["b2"])


def classify(params, s):
    """τ_u: s (B, d') -> logits (B, C)."""
    return s @ params["head_w"] + params["head_b"]


def apply(params, x):
    s = features(params, x)
    return s, classify(params, s)


def num_params(params) -> int:
    return sum(int(p.size) for p in jax.tree.leaves(params))
