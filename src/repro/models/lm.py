"""Decoder-only LM spine shared by all non-enc-dec architectures.

Params are a pure pytree (dicts/lists of arrays); the static structure
(segment kinds, shared-block insertion points) is derived from the config.
`forward` covers train (features+logits) and prefill (also returns caches);
`decode_step` is the one-token serve path. Features = post-final-norm last
hidden states — the `d'`-dimensional representations the paper shares.
"""
from __future__ import annotations

from typing import Any, Dict, List

import jax
import jax.numpy as jnp

from repro.models import blocks
from repro.nn import layers, rope as rope_lib


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def shared_points(cfg) -> List[int]:
    """Cumulative-layer counts after which the shared attn block runs."""
    if not cfg.shared_attn_period:
        return []
    k = cfg.shared_attn_period
    return [i for i in range(k, cfg.num_layers + 1, k)]


def init_lm(key, cfg):
    dt = _dtype(cfg)
    ks = layers.split(key, 5)
    params: Dict[str, Any] = {}
    if cfg.input_kind == "tokens":
        params["embed"] = layers.embed_init(ks[0], cfg.vocab_size, cfg.d_model, dt)
    params["segments"] = [s["params"] for s in blocks.init_segments(ks[1], cfg, dt)]
    params["final_norm"] = layers.init_norm(cfg.norm_kind, cfg.d_model, dt)
    if not cfg.tie_embeddings or cfg.input_kind != "tokens":
        params["lm_head"] = layers.dense_init(ks[2], cfg.d_model,
                                              cfg.vocab_size, dt)
    if cfg.shared_attn_period:
        params["shared"] = blocks.init_block(ks[3], cfg, "attn", dt)
    return params


def _embed(params, cfg, batch):
    if cfg.input_kind == "tokens":
        return jnp.take(params["embed"], batch["tokens"], axis=0)
    return batch["embeddings"].astype(_dtype(cfg))


def _head(params, cfg, features):
    w = params.get("lm_head")
    if w is None:                                   # tied
        w = params["embed"].T
    return jnp.einsum("bsd,dv->bsv", features, w)


def _positions(cfg, batch, B, S, offset=0):
    pos = batch.get("positions")
    if pos is None:
        pos = rope_lib.default_positions(B, S, cfg.rope_kind, offset=offset)
    return pos


def forward(params, cfg, batch, *, mode: str = "train", window: int = 0):
    """-> dict(features, logits, aux, caches). window>0 = sliding-window attn."""
    x = _embed(params, cfg, batch)
    B, S = x.shape[:2]
    positions = _positions(cfg, batch, B, S)
    segs = blocks.segments_of(cfg)
    points = shared_points(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    caches: Dict[str, Any] = {"segments": [], "shared": []}
    count = 0
    for seg_params, (kind, n) in zip(params["segments"], segs):
        x, aux, cache = blocks.run_segment(
            seg_params, cfg, kind, x, positions, window=window, mode=mode)
        aux_total = aux_total + aux
        caches["segments"].append(cache)
        count += n
        if count in points and count < cfg.num_layers + 1:
            x, aux2, sc = blocks.apply_block(
                params["shared"], cfg, "attn", x, positions, window=window,
                mode=mode)
            aux_total = aux_total + aux2
            caches["shared"].append(sc)
    features = layers.apply_norm(cfg.norm_kind, params["final_norm"], x,
                                 cfg.norm_eps)
    logits = _head(params, cfg, features)
    return {"features": features, "logits": logits, "aux": aux_total,
            "caches": caches if mode == "prefill" else None}


def decode_step(params, cfg, batch, caches, *, window: int = 0,
                cache_index=None, masked: bool = False):
    """One-token decode. batch: tokens (B,1) (or embeddings (B,1,d)).

    caches: pytree from `forward(mode="prefill")` (or `init_cache`).
    Default (dry-run) semantics: the new token overwrites the LAST cache
    slot, every slot valid — cost identical to a real rolling decode step.
    Serving semantics: pass `cache_index` (slot to write) and `masked=True`
    (attend only to slots <= cache_index) to generate incrementally into a
    fixed-size cache without reshaping/recompiling.
    """
    x = _embed(params, cfg, batch)
    B = x.shape[0]
    S_ctx = _cache_len(cfg, caches)
    positions = batch.get("positions")
    if positions is None:
        offset = (S_ctx - 1) if cache_index is None else cache_index
        positions = rope_lib.default_positions(B, 1, cfg.rope_kind,
                                               offset=offset)
    segs = blocks.segments_of(cfg)
    points = shared_points(cfg)
    new_caches: Dict[str, Any] = {"segments": [], "shared": []}
    count = 0
    shared_i = 0
    for seg_params, (kind, n), cache in zip(params["segments"], segs,
                                            caches["segments"]):
        x, _, nc = blocks.run_segment(
            seg_params, cfg, kind, x, positions, window=window, mode="decode",
            cache=cache, cache_index=cache_index, masked=masked)
        new_caches["segments"].append(nc)
        count += n
        if count in points and count < cfg.num_layers + 1:
            x, _, sc = blocks.apply_block(
                params["shared"], cfg, "attn", x, positions, window=window,
                mode="decode", cache=caches["shared"][shared_i],
                cache_index=cache_index, masked=masked)
            new_caches["shared"].append(sc)
            shared_i += 1
    features = layers.apply_norm(cfg.norm_kind, params["final_norm"], x,
                                 cfg.norm_eps)
    logits = _head(params, cfg, features)
    return {"features": features, "logits": logits, "caches": new_caches}


def _cache_len(cfg, caches) -> int:
    for seg, (kind, _) in zip(caches["segments"], blocks.segments_of(cfg)):
        if kind == "attn":
            if cfg.is_mla:
                return seg.shape[2]          # (L,B,S,r+dr)
            return seg[0].shape[2]           # (L,B,S,G,hd)
    if caches["shared"]:
        sc = caches["shared"][0]
        return sc.shape[1] if cfg.is_mla else sc[0].shape[1]
    return 1


def init_cache(cfg, batch_size: int, ctx_len: int, *, window: int = 0):
    """Zero caches shaped for decode at context length ctx_len (ShapeDtype-
    compatible: used by dryrun via eval_shape and by serve.py for real)."""
    dt = _dtype(cfg)
    S = min(ctx_len, window) if window else ctx_len
    segs = blocks.segments_of(cfg)
    caches: Dict[str, Any] = {"segments": [], "shared": []}

    def attn_cache(n):
        if cfg.is_mla:
            return jnp.zeros((n, batch_size, S,
                              cfg.kv_lora_rank + cfg.qk_rope_dim), dt)
        return (jnp.zeros((n, batch_size, S, cfg.num_kv_heads, cfg.head_dim), dt),
                jnp.zeros((n, batch_size, S, cfg.num_kv_heads, cfg.v_head_dim), dt))

    for kind, n in segs:
        if kind == "attn":
            caches["segments"].append(attn_cache(n))
        elif kind == "mamba":
            C = cfg.d_inner + 2 * cfg.ssm_state
            caches["segments"].append(
                (jnp.zeros((n, batch_size, cfg.ssm_conv - 1, C), dt),
                 jnp.zeros((n, batch_size, cfg.mamba_heads, cfg.mamba_head_dim,
                            cfg.ssm_state), jnp.float32)))
        elif kind == "mlstm":
            di = 2 * cfg.d_model
            P = di // cfg.num_heads
            caches["segments"].append(
                (jnp.zeros((n, batch_size, cfg.ssm_conv - 1, di), dt),
                 jnp.zeros((n, batch_size, cfg.num_heads, P + 1, P), jnp.float32)))
        elif kind == "slstm":
            d = cfg.d_model
            z = jnp.zeros((n, batch_size, d), jnp.float32)
            caches["segments"].append((z, z, jnp.full((n, batch_size, d), -30.0,
                                                      jnp.float32), z))
    n_shared = len(shared_points(cfg))
    for _ in range(n_shared):
        c = attn_cache(1)
        c = jax.tree.map(lambda a: a[0], c)   # shared block is unstacked
        caches["shared"].append(c)
    return caches


def pad_cache_for_decode(cfg, caches):
    """Append one empty slot to every attention cache seq axis.

    decode_step writes the new token at the LAST cache slot; padding a
    prefill(S-1)-cache to length S makes the decode an exact append —
    decode(x_S | prefill(x_0..x_{S-1})) equals forward(x_0..x_S) at the last
    position. SSM/xLSTM caches are recurrent states and need no padding.
    """
    def pad_attn(c):
        return jax.tree.map(
            lambda a: jnp.pad(a, [(0, 1 if i == 2 else 0)
                                  for i in range(a.ndim)]), c)

    out = {"segments": [], "shared": []}
    for (kind, _), cache in zip(blocks.segments_of(cfg), caches["segments"]):
        out["segments"].append(pad_attn(cache) if kind == "attn" else cache)
    for cache in caches["shared"]:
        sc = jax.tree.map(
            lambda a: jnp.pad(a, [(0, 1 if i == 1 else 0)
                                  for i in range(a.ndim)]), cache)
        out["shared"].append(sc)
    return out
