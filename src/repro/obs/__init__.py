"""Fleet observability: in-jit round metrics, phase trace spans, sinks.

Three tiers, one opt-in surface:

  1. `repro.obs.metrics` — a fixed-shape `RoundTelemetry` pytree computed
     INSIDE the engines' jitted round steps (ring occupancy/fill, owner
     diversity, staleness and commit-lag histograms, pending depth,
     prototype drift, per-bucket loss/grad-norm), REPLICATED on a mesh
     and oracle-checked bit-for-bit between engines;
  2. `repro.obs.trace` — a `TraceRecorder` wrapping round phases in
     jax.profiler annotations and emitting Chrome trace-event JSON
     (open in Perfetto), with opt-in `profile=True` barriers;
  3. `repro.obs.sink` / `repro.obs.report` — a JSONL per-round writer and
     the `python -m repro.obs.report` CLI that renders a run summary.

Engines take `telemetry=` (True for in-jit metrics only, or a
`TelemetryConfig` to add sinks/tracing); the default None keeps every
round step's traced program byte-identical to a telemetry-free build —
free when off, and the CI `telemetry` gate bounds the cost when on.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.obs import metrics, sink, trace  # noqa: F401  (re-exported tiers)
# repro.obs.report is deliberately NOT imported here: it is the
# `python -m repro.obs.report` CLI, and importing it from the package
# would make runpy warn about the double module identity.
from repro.obs.metrics import (  # noqa: F401
    STALE_BINS, RoundTelemetry, round_telemetry, shard_summary, to_record)
from repro.obs.sink import JsonlWriter, read_jsonl  # noqa: F401
from repro.obs.trace import NULL_SPAN, TraceRecorder, null_span  # noqa: F401


@dataclass(frozen=True)
class TelemetryConfig:
    """What to observe and where it goes.

    metrics: compute the in-jit RoundTelemetry each round (adds a
      `telemetry` entry to every round record). jsonl: stream each round
      record to this JSONL path. trace: write phase spans to this Chrome
      trace-event JSON path (rewritten every round). profile: make each
      span block_until_ready on its phase's outputs — honest device-time
      attribution at the cost of pipelining (implies span recording even
      without a trace path, for programmatic access via the recorder)."""
    metrics: bool = True
    jsonl: Optional[str] = None
    trace: Optional[str] = None
    profile: bool = False


def resolve(telemetry) -> Optional[TelemetryConfig]:
    """The engines' `telemetry=` kwarg: None/False -> off (no config),
    True -> in-jit metrics only, or a TelemetryConfig verbatim."""
    if telemetry is None or telemetry is False:
        return None
    if telemetry is True:
        return TelemetryConfig()
    if isinstance(telemetry, TelemetryConfig):
        return telemetry
    raise TypeError(
        f"telemetry= expects None, bool or obs.TelemetryConfig; got "
        f"{type(telemetry).__name__}")
