"""Run-report CLI: render a telemetry JSONL run as per-round tables.

    PYTHONPATH=src python -m repro.obs.report RUN.jsonl [--last N]

Reads the per-round records both engines write through the JSONL sink
(repro.obs.sink) and prints: a per-round table (accuracy, participants,
commits, relay occupancy / owner diversity, pending depth, late commits,
stale reads, prototype drift, mean loss), the aggregate commit-lag and
final staleness histograms, and the communication ledger (from the same
`comm.round_floats` accounting the engines bill through — floats, and MB
assuming 4-byte floats like the benchmark sweeps). Records without a
`telemetry` entry (telemetry metrics disabled, sink still on) degrade to
the accuracy/comm columns.
"""
from __future__ import annotations

import argparse
import sys

from repro.obs import metrics as obs_metrics
from repro.obs.sink import read_jsonl

BYTES_PER_FLOAT = 4


def _fmt_hist(hist) -> str:
    return " ".join(str(int(v)) for v in hist)


def render(records, last: int = 0) -> str:
    """The report as one string (the CLI prints it; tests assert on it)."""
    if not records:
        return "(empty run: no round records)\n"
    shown = records[-last:] if last else records
    lines = []
    n_rounds = len(records)
    has_telem = any("telemetry" in r for r in records)
    lines.append(f"run report: {n_rounds} rounds"
                 + ("" if last == 0 or last >= n_rounds
                    else f" (showing last {len(shown)})"))
    lines.append("")
    header = (f"{'round':>5} {'acc':>7} {'parts':>5} {'commits':>7} "
              f"{'occ':>4} {'div':>4} {'pend':>4} {'late':>4} "
              f"{'stale':>5} {'drift':>8} {'loss':>8}")
    lines.append(header)
    lines.append("-" * len(header))
    for r in shown:
        t = r.get("telemetry")
        if t is None:
            occ = div = pend = late = stale = drift = loss = "-"
        else:
            occ = int(t["occupancy"])
            div = int(t["owner_diversity"])
            pend = int(t["pending_depth"])
            late = sum(int(v) for v in t["commit_hist"][1:])
            stale = int(t["stale_reads"])
            drift = f"{float(t['proto_drift']):.4f}"
            nb = [float(v) for v in t["bucket_loss"]]
            loss = f"{sum(nb) / len(nb):.4f}"
        acc = (f"{r['acc_mean']:.4f}" if "acc_mean" in r else "-")
        lines.append(
            f"{r['round']:>5} {acc:>7} "
            f"{len(r.get('participants', [])):>5} "
            f"{len(r.get('commits', [])):>7} {occ:>4} {div:>4} {pend:>4} "
            f"{late:>4} {stale:>5} {drift:>8} {loss:>8}")
    lines.append("")

    if has_telem:
        agg = [0] * obs_metrics.STALE_BINS
        for r in records:
            t = r.get("telemetry")
            if t:
                for i, v in enumerate(t["commit_hist"]):
                    agg[i] += int(v)
        lines.append(f"commit-lag histogram (all rounds, lag 0.."
                     f"{obs_metrics.STALE_BINS - 1}+): {_fmt_hist(agg)}")
        for r in reversed(records):
            t = r.get("telemetry")
            if t:
                lines.append(
                    f"staleness histogram (final round, age 0.."
                    f"{obs_metrics.STALE_BINS - 1}+): "
                    f"{_fmt_hist(t['stale_hist'])}")
                lines.append(
                    f"per-class fill (final round): {_fmt_hist(t['fill'])}")
                break
        lines.append("")

    up = sum(float(r.get("comm_up", 0.0)) for r in records)
    down = sum(float(r.get("comm_down", 0.0)) for r in records)
    mb = BYTES_PER_FLOAT * (up + down) / 1e6
    lines.append(f"comm: up {up:.0f} floats, down {down:.0f} floats "
                 f"({mb:.3f} MB at {BYTES_PER_FLOAT} B/float)")
    return "\n".join(lines) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Render a per-round summary from a telemetry JSONL run")
    ap.add_argument("jsonl", help="path to a run's JSONL metrics file")
    ap.add_argument("--last", type=int, default=0,
                    help="only show the last N rounds in the table")
    args = ap.parse_args(argv)
    print(render(read_jsonl(args.jsonl), last=args.last), end="")
    return 0


if __name__ == "__main__":
    sys.exit(main())
