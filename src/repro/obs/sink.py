"""JSONL metrics sink: one line per round record, flushed as written.

Tier 3 of the telemetry layer, the durable half of the reporting surface:
both engines (and the launch/benchmark paths) hand their per-round record
dict to a `JsonlWriter`, and `python -m repro.obs.report` renders the file
back into staleness/occupancy/comm tables. JSONL because runs are streams:
a crashed or interrupted run keeps every completed round, `tail -f` works,
and readers never need the whole file in memory.
"""
from __future__ import annotations

import json
import os

import numpy as np


def _np_default(x):
    """json.dumps fallback for the numpy scalars/arrays that leak into
    round records (accuracies, participant ids)."""
    if isinstance(x, np.ndarray):
        return x.tolist()
    if isinstance(x, np.generic):
        return x.item()
    raise TypeError(f"not JSON-serializable: {type(x)!r}")


class JsonlWriter:
    def __init__(self, path: str):
        self.path = path
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._f = open(path, "w")

    def write(self, record: dict):
        self._f.write(json.dumps(record, default=_np_default) + "\n")
        self._f.flush()

    def close(self):
        if self._f is not None:
            self._f.close()
            self._f = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


def read_jsonl(path: str) -> list:
    """Load a JSONL run back into a list of round records."""
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]
