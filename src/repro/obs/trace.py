"""Host trace spans around the round phases, Chrome-trace-event output.

Tier 2 of the telemetry layer: a `TraceRecorder` whose `span(name)` context
manager wraps a round phase (teacher read / update / upload / commit /
eval) in a `jax.profiler.TraceAnnotation` — so the phases show up inside a
`jax.profiler.trace` capture — while recording wall-clock begin/end on the
host and accumulating complete ("ph": "X") Chrome trace events that
`write()` dumps as JSON loadable in Perfetto (https://ui.perfetto.dev,
"Open trace file") or chrome://tracing.

Async dispatch caveat: JAX returns before the device finishes, so a bare
span around a jitted call times the DISPATCH, not the work. For honest
phase attribution pass `profile=True` and hand each span the outputs to
block on (`sp.block(out)`): the span then calls `jax.block_until_ready`
at exit, charging the device time to the phase that ran it. The default
(profile off) keeps spans free of barriers so tracing never perturbs the
pipelining it observes — span times then mean "host time until dispatch
returned", which is still the right lens for dispatch-bound fleets.

In-jit phase labels are separate and always on: the round steps wrap their
phases in `jax.named_scope`, which costs nothing at runtime (it only names
HLO metadata) and makes XLA profiles readable without this recorder.
"""
from __future__ import annotations

import json
import os
import time

import jax


class _NullSpan:
    """No-op span: `null_span` returns this singleton so engines can write
    `with self._span("phase") as sp: ...; sp.block(out)` unconditionally."""

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def block(self, outputs):
        return outputs


NULL_SPAN = _NullSpan()


def null_span(name: str, **args):
    """Span factory with TraceRecorder.span's signature that records
    nothing — what engines bind when tracing is off."""
    return NULL_SPAN


class _Span:
    def __init__(self, rec: "TraceRecorder", name: str, args: dict):
        self._rec, self._name, self._args = rec, name, args
        self._ann = jax.profiler.TraceAnnotation(name)
        self._sync = None

    def block(self, outputs):
        """Register device outputs to block on at span exit (profile mode
        only). Returns them unchanged so call sites stay expression-shaped."""
        self._sync = outputs
        return outputs

    def __enter__(self):
        self._ann.__enter__()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        if self._rec.profile and self._sync is not None and exc_type is None:
            jax.block_until_ready(self._sync)
        t1 = time.perf_counter()
        self._ann.__exit__(exc_type, exc, tb)
        self._rec._add(self._name, self._t0, t1, self._args)
        return False


class TraceRecorder:
    """Collects phase spans as Chrome trace events.

    path: default destination for `write()` (the engines rewrite it after
    every round, so the trace is inspectable mid-run and nothing is lost
    on interrupt). profile: block on each span's registered outputs at
    exit — see the module docstring for the fidelity/perturbation trade."""

    def __init__(self, path: str = None, profile: bool = False):
        self.path = path
        self.profile = profile
        self.events = []
        self._origin = time.perf_counter()

    def span(self, name: str, **args):
        return _Span(self, name, args)

    def _add(self, name: str, t0: float, t1: float, args: dict):
        ev = {"name": name, "ph": "X", "pid": 1, "tid": 1,
              "ts": round((t0 - self._origin) * 1e6, 3),
              "dur": round((t1 - t0) * 1e6, 3)}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def write(self, path: str = None):
        path = path or self.path
        if not path:
            return
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump({"traceEvents": self.events,
                       "displayTimeUnit": "ms"}, f)
