"""In-jit round telemetry: one fixed-shape `RoundTelemetry` pytree per round.

The headline claims of the paper — flat communication, scalability in N,
utility under staleness — are measured claims, and the engines that
simulate them (async event log, download-lag history, hetero buckets,
meshes) were invisible outside ad-hoc prints. This module computes the
round's observability surface INSIDE the existing jitted round step, so
telemetry is free when off (a static flag — the traced program is
unchanged) and cheap when on (a handful of reductions over state the step
already holds; the CI `telemetry` gate bounds the overhead).

Every leaf is fixed-shape, mesh-ready (all leaves REPLICATED — telemetry
summarizes the shared relay, never per-client state; see `out_spec`), and
oracle-checked: the sequential trainer computes the SAME function over its
bit-equal ring state (plus host-side pending/commit counters), so the
integer bookkeeping leaves are bit-identical across engines while the
float leaves (drift, per-bucket losses) carry the same vmap-association
tolerance as the weights themselves (tests/oracles.assert_telemetry_match).

Leaf semantics (C = num_classes, B = STALE_BINS, n_b = bucket count):

  occupancy       ()   int32  live ring slots (owner != EMPTY_OWNER)
  fill            (C,) int32  valid observations per class across the ring
  owner_diversity ()   int32  distinct real clients (owner >= 0) owning
                              at least one live slot — seeds excluded
  stale_hist      (B,) int32  age histogram of live slots in the
                              POST-round state, age = clock − stamp
                              clipped into bin B−1 (what a round-fresh
                              teacher read next round would see)
  pending_depth   ()   int32  in-flight uploads still parked after the
                              round (0 for synchronous fleets)
  commit_hist     (B,) int32  this round's commits binned by commit lag
                              (commit round − birth round); bin 0 is the
                              fresh delay-0 uploads, so late commits =
                              commit_hist[1:].sum()
  stale_reads     ()   int32  present clients whose downlink came from a
                              stale snapshot (download delay > 0)
  proto_drift     ()   f32    ||global_protos − previous round's||₂
  bucket_loss     (n_b,) f32  mean last-batch total loss over the
                              bucket's PRESENT clients
  bucket_grad_norm (n_b,) f32 same reduction over the global grad norm
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.relay import placement
from repro.relay.base import EMPTY_OWNER

# Fixed histogram width shared by stale_hist and commit_hist: ages/lags
# 0..STALE_BINS-2 get their own bin, everything older clips into the last.
STALE_BINS = 8


class RoundTelemetry(NamedTuple):
    occupancy: jax.Array          # () int32
    fill: jax.Array               # (C,) int32
    owner_diversity: jax.Array    # () int32
    stale_hist: jax.Array         # (STALE_BINS,) int32
    pending_depth: jax.Array      # () int32
    commit_hist: jax.Array        # (STALE_BINS,) int32
    stale_reads: jax.Array        # () int32
    proto_drift: jax.Array        # () f32
    bucket_loss: jax.Array        # (n_buckets,) f32
    bucket_grad_norm: jax.Array   # (n_buckets,) f32


# Integer leaves are derived from the exactly-matched ring/clock/pending
# bookkeeping and must agree bit-for-bit across engines; float leaves
# inherit the engines' vmap-association tolerance.
EXACT_LEAVES = ("occupancy", "fill", "owner_diversity", "stale_hist",
                "pending_depth", "commit_hist", "stale_reads")
FLOAT_LEAVES = ("proto_drift", "bucket_loss", "bucket_grad_norm")


def out_spec(telem: RoundTelemetry):
    """Placement declaration (relay/placement.py): every telemetry leaf is
    a fleet-wide summary of REPLICATED relay/pending reductions — nothing
    is per-client-resident, so the whole pytree replicates on a mesh."""
    return placement.like(telem, placement.REPLICATED)


def _single_summary(state, n_clients: int):
    """(occupancy, fill, owner_diversity, stale_hist) of ONE relay state.

    Layout-generic across the policy states: flat/staleness rings carry
    `valid (cap, C)` / `owner (cap,)`, the per-class layout carries
    `valid (C, cap_c)` / `owner (C, cap_c)` — discriminated by the ptr
    rank (per_class keeps one write pointer per class)."""
    per_class = state.ptr.ndim == 1
    owner = state.owner.reshape(-1)
    stamp = state.stamp.reshape(-1)
    vi = state.valid.astype(jnp.int32)
    fill = jnp.sum(vi, axis=1) if per_class else jnp.sum(vi, axis=0)
    live = owner != EMPTY_OWNER
    li = live.astype(jnp.int32)
    occupancy = jnp.sum(li)
    # distinct real owners (seeds' owner=-1 excluded): sort-based exact
    # count — dead slots sort to a sentinel, a live owner counts where it
    # differs from its sorted predecessor. Unlike a scatter onto an (N,)
    # count vector this is id-space independent, which streaming arrivals
    # need: external ids are unbounded while seats stay few.
    sentinel = jnp.iinfo(jnp.int32).max
    key = jnp.sort(jnp.where(live & (owner >= 0), owner, sentinel))
    isreal = key != sentinel
    distinct = isreal & jnp.concatenate(
        [jnp.ones((1,), bool), key[1:] != key[:-1]])
    owner_diversity = jnp.sum(distinct.astype(jnp.int32))
    age = jnp.clip(state.clock - stamp, 0, STALE_BINS - 1)
    stale_hist = jnp.zeros((STALE_BINS,), jnp.int32).at[age].add(li)
    return occupancy, fill, owner_diversity, stale_hist


def relay_summary(state, n_clients: int):
    """(occupancy, fill, owner_diversity, stale_hist) of a relay state.

    Sharded relay states (relay/shards.py — every inner leaf stacked on a
    leading (S,) axis) summarize per shard and reduce: occupancy/fill/
    stale_hist sum, and because a client hashes to exactly ONE shard,
    distinct owners across shards is the sum of per-shard counts too."""
    if hasattr(state, "shards"):
        occ, fill, div, hist = jax.vmap(
            lambda s: _single_summary(s, n_clients))(state.shards)
        return (jnp.sum(occ), jnp.sum(fill, axis=0), jnp.sum(div),
                jnp.sum(hist, axis=0))
    return _single_summary(state, n_clients)


def shard_summary(state, n_clients: int = 0) -> dict:
    """Host-side PER-SHARD summary — the population sweep's report surface
    (occupancy, owner diversity and the age histogram per relay shard).
    Single-relay states report themselves as one shard."""
    if hasattr(state, "shards"):
        occ, fill, div, hist = jax.vmap(
            lambda s: _single_summary(s, n_clients))(state.shards)
    else:
        o, f, d, h = _single_summary(state, n_clients)
        occ, fill, div, hist = o[None], f[None], d[None], h[None]
    occ, div, hist = jax.device_get((occ, div, hist))
    return {"occupancy": np.asarray(occ).tolist(),
            "owner_diversity": np.asarray(div).tolist(),
            "stale_hist": np.asarray(hist).tolist()}


def round_telemetry(prev_state, new_state, n_clients: int, *, mask,
                    loss_parts, gnorm_parts, mask_parts,
                    pending=None, pending_pre=None, round_idx=None,
                    delays=None, dl=None,
                    commit_hist=None, pending_depth=None) -> RoundTelemetry:
    """The one telemetry computation, shared by every engine path.

    prev/new_state: the relay state at round start / end (drift + summary).
    mask: (N,) bool participation. loss/gnorm/mask_parts: per-bucket tuples
    of (k_b,) arrays in bucket order, absent clients zeroed/masked — one
    entry for homogeneous fleets.

    The commit-lag histogram has two sources: the vectorized async step
    passes the PRE-commit pending buffer (`pending_pre`, `round_idx`,
    `delays`) and the lags are recomputed in-jit from the same due-event
    predicate `commit_and_park` uses; the sequential oracle (which replays
    events host-side and holds no PendingState) passes its host-counted
    `commit_hist` / `pending_depth` directly. Both reduce the identical
    event multiset, which is what run_matched pins bit-for-bit."""
    occupancy, fill, owner_diversity, stale_hist = relay_summary(
        new_state, n_clients)

    if pending_depth is None:
        pending_depth = (jnp.sum(pending.live.astype(jnp.int32))
                         if pending is not None
                         else jnp.zeros((), jnp.int32))
    else:
        pending_depth = jnp.asarray(pending_depth, jnp.int32).reshape(())

    if commit_hist is None:
        fresh = mask & (delays == 0) if delays is not None else mask
        commit_hist = jnp.zeros((STALE_BINS,), jnp.int32).at[0].add(
            jnp.sum(fresh.astype(jnp.int32)))
        if pending_pre is not None and pending_pre.d_max > 0:
            due = (pending_pre.live
                   & (pending_pre.commit == round_idx)).astype(jnp.int32)
            lag = jnp.clip(round_idx - pending_pre.birth, 0, STALE_BINS - 1)
            commit_hist = commit_hist.at[lag.reshape(-1)].add(
                due.reshape(-1))
    else:
        commit_hist = jnp.asarray(commit_hist, jnp.int32)

    stale_reads = (jnp.sum((mask & (dl > 0)).astype(jnp.int32))
                   if dl is not None else jnp.zeros((), jnp.int32))

    dp = new_state.global_protos - prev_state.global_protos
    proto_drift = jnp.sqrt(jnp.sum(jnp.square(dp))).astype(jnp.float32)

    bl, bg = [], []
    for lp, gp, mp in zip(loss_parts, gnorm_parts, mask_parts):
        n_b = jnp.maximum(jnp.sum(mp.astype(jnp.float32)), 1.0)
        bl.append(jnp.sum(lp) / n_b)
        bg.append(jnp.sum(gp) / n_b)
    return RoundTelemetry(
        occupancy=occupancy, fill=fill, owner_diversity=owner_diversity,
        stale_hist=stale_hist, pending_depth=pending_depth,
        commit_hist=commit_hist, stale_reads=stale_reads,
        proto_drift=proto_drift,
        bucket_loss=jnp.stack(bl).astype(jnp.float32),
        bucket_grad_norm=jnp.stack(bg).astype(jnp.float32))


def make_telemetry_fn(n_clients: int, asynchronous: bool = False,
                      lagged: bool = False):
    """Jitted round_telemetry for the BUCKETED vectorized engine, which
    computes telemetry in one extra dispatch after the shared relay commit
    (its per-bucket steps and the commit are separate jits, so there is no
    single step to fuse into). Signature varies with the fleet's clocks —
    trailing args are (pending_pre, pending_post, round_idx, delays) when
    asynchronous, then (dl,) when download-lagged. One trace per engine."""

    def fn(prev_state, new_state, mask, mask_parts, loss_parts,
           gnorm_parts, *rest):
        rest = list(rest)
        pending_pre = pending = round_idx = delays = dl = None
        if asynchronous:
            pending_pre, pending, round_idx, delays = rest[:4]
            rest = rest[4:]
        if lagged:
            dl = rest[0]
        return round_telemetry(
            prev_state, new_state, n_clients, mask=mask,
            loss_parts=loss_parts, gnorm_parts=gnorm_parts,
            mask_parts=mask_parts, pending=pending,
            pending_pre=pending_pre, round_idx=round_idx, delays=delays,
            dl=dl)

    return jax.jit(fn)


def make_host_telemetry_fn(n_clients: int):
    """Jitted round_telemetry for the SEQUENTIAL oracle: same relay-state
    reductions over its bit-equal ring, with the event-log quantities the
    oracle already tracks host-side (commit list lags, queue depth,
    download delays) passed in as small arrays. One trace per trainer."""

    def fn(prev_state, new_state, mask, mask_parts, loss_parts,
           gnorm_parts, commit_hist, pending_depth, dl):
        return round_telemetry(
            prev_state, new_state, n_clients, mask=mask,
            loss_parts=loss_parts, gnorm_parts=gnorm_parts,
            mask_parts=mask_parts, commit_hist=commit_hist,
            pending_depth=pending_depth, dl=dl)

    return jax.jit(fn)


def to_record(telem: RoundTelemetry) -> dict:
    """JSON-safe host dict of one round's telemetry: scalars become python
    int/float, vectors become lists — the `rec["telemetry"]` entry in both
    engines' round records and the JSONL sink payload. One device_get for
    the whole pytree (not one sync per leaf — this runs every round)."""
    host = jax.device_get(tuple(telem))
    out = {}
    for name, leaf in zip(RoundTelemetry._fields, host):
        a = np.asarray(leaf)
        out[name] = a.item() if a.ndim == 0 else a.tolist()
    return out
