"""Federated data partitioning: uniform (paper's setup: 'split uniformly at
random across N users') and Dirichlet label-skew (the standard non-IID
stressor, used by our beyond-paper heterogeneity experiments)."""
from __future__ import annotations

from typing import List, Tuple

import numpy as np


def uniform_split(x: np.ndarray, y: np.ndarray, n_clients: int,
                  seed: int = 0) -> List[Tuple[np.ndarray, np.ndarray]]:
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(x))
    parts = np.array_split(idx, n_clients)
    return [(x[p], y[p]) for p in parts]


def dirichlet_split(x: np.ndarray, y: np.ndarray, n_clients: int,
                    alpha: float = 0.5, seed: int = 0,
                    num_classes: int | None = None):
    rng = np.random.default_rng(seed)
    C = num_classes or int(y.max()) + 1
    client_idx: List[List[int]] = [[] for _ in range(n_clients)]
    for c in range(C):
        ids = np.where(y == c)[0]
        rng.shuffle(ids)
        props = rng.dirichlet([alpha] * n_clients)
        cuts = (np.cumsum(props) * len(ids)).astype(int)[:-1]
        for i, part in enumerate(np.split(ids, cuts)):
            client_idx[i].extend(part.tolist())
    out = []
    for ids in client_idx:
        ids = np.array(sorted(ids), int)
        out.append((x[ids], y[ids]))
    return out
