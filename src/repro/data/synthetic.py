"""Synthetic datasets (no MNIST/CIFAR offline — see DESIGN.md §6).

- `class_images`: class-conditional image data with controllable difficulty:
  each class is a mixture of spatial Gaussian blobs + class-specific frequency
  pattern + noise. Learnable by a LeNet-scale CNN to >90% with enough data,
  and hard enough that the low-data regime separates frameworks — the regime
  the paper's Table 1 probes.
- `token_stream`: deterministic synthetic LM corpus with n-gram structure so
  cross-entropy meaningfully decreases during training.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def class_images(n: int, *, num_classes: int = 10, image: int = 28,
                 channels: int = 1, noise: float = 0.5, modes: int = 4,
                 seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """-> x (n, image, image, channels) float32, y (n,) int32.

    Each class is a mixture of `modes` sub-templates ("styles", like
    handwriting variants in MNIST): the modes of a class share two anchor
    blobs (the class identity) but differ in a third blob and grating phase.
    A small local dataset under-covers the modes — exactly the sparse-data
    regime of the paper's Table 1, where collaborating on class-level feature
    structure transfers across clients.
    """
    rng = np.random.default_rng(seed)
    y = rng.integers(0, num_classes, size=n).astype(np.int32)
    m_id = rng.integers(0, modes, size=n)
    xs = np.zeros((n, image, image, channels), np.float32)
    yy, xx = np.meshgrid(np.linspace(-1, 1, image), np.linspace(-1, 1, image),
                         indexing="ij")
    tpl_rng = np.random.default_rng(12345)
    blob = lambda cx, cy, s: np.exp(-((xx - cx) ** 2 + (yy - cy) ** 2)
                                    / (2 * s * s))
    templates = []                       # [class][mode] -> (image, image)
    for c in range(num_classes):
        base = sum(blob(*tpl_rng.uniform(-0.6, 0.6, 2),
                        tpl_rng.uniform(0.15, 0.3)) for _ in range(2))
        fx, fy = tpl_rng.uniform(2, 6, 2)
        per_class = []
        for m in range(modes):
            t = base + blob(*tpl_rng.uniform(-0.7, 0.7, 2),
                            tpl_rng.uniform(0.1, 0.25)) * 1.5
            ph = tpl_rng.uniform(0, 2 * np.pi)
            t = t + 0.5 * np.sin(fx * np.pi * xx + fy * np.pi * yy + ph)
            per_class.append(t / np.abs(t).max())
        templates.append(per_class)
    for i in range(n):
        t = templates[y[i]][m_id[i]]
        shift = rng.integers(-2, 3, size=2)
        img = np.roll(np.roll(t, shift[0], axis=0), shift[1], axis=1)
        img = img * rng.uniform(0.8, 1.2) + rng.normal(0, noise, (image, image))
        xs[i, :, :, 0] = img
    return np.clip(xs, -2, 2).astype(np.float32), y


def token_stream(n_tokens: int, *, vocab: int = 512, order: int = 2,
                 seed: int = 0) -> np.ndarray:
    """Markov token stream: learnable structure (per-context peaked
    next-token distributions)."""
    rng = np.random.default_rng(seed)
    # sparse transition structure: each context maps to 4 likely tokens
    n_ctx = 4096
    ctx_next = rng.integers(0, vocab, size=(n_ctx, 4))
    toks = np.zeros(n_tokens, np.int32)
    toks[:order] = rng.integers(0, vocab, order)
    h = 0
    for i in range(order, n_tokens):
        h = (h * 31 + int(toks[i - 1])) % n_ctx
        if rng.random() < 0.8:
            toks[i] = ctx_next[h, rng.integers(4)]
        else:
            toks[i] = rng.integers(vocab)
    return toks


def lm_batches(tokens: np.ndarray, batch: int, seq: int, steps: int,
               seed: int = 0):
    """Yield dicts(tokens (B,S), labels (B,S)) sliced from the stream."""
    rng = np.random.default_rng(seed)
    n = len(tokens) - seq - 1
    for _ in range(steps):
        idx = rng.integers(0, n, size=batch)
        x = np.stack([tokens[i:i + seq] for i in idx])
        y = np.stack([tokens[i + 1:i + seq + 1] for i in idx])
        yield {"tokens": x, "labels": y}
