"""Zamba2-1.2B [arXiv:2411.15242] — hybrid: 38 Mamba2 blocks (state 64) with
one *shared-weight* full-attention block (MHA kv=32, d_ff 8192) applied every
6 layers. Attention-free backbone scan -> long_500k runs natively."""
from repro.types import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    block_pattern=tuple(["mamba"] * 38),
    shared_attn_period=6,
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_chunk=128,
    rope_kind="rope",
    mlp_kind="swiglu",
    long_context_mode="native",
)
