"""Qwen2-VL-7B [arXiv:2409.12191] — VLM backbone: M-RoPE (t/h/w sections),
GQA kv=4. Vision frontend (ViT + projector) is the allowed STUB:
input_specs provides precomputed patch embeddings (B, S, d_model); positions
are the (B, S, 3) multimodal rope ids."""
from repro.types import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    rope_kind="mrope",
    mlp_kind="swiglu",
    input_kind="embeddings",
    long_context_mode="swa",
)
