"""ChatGLM3-6B [arXiv:2406.12793] — dense, RoPE-2D (rotary on half the head
dims), GQA with 2 KV heads."""
from repro.types import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    rope_kind="rope2d",
    mlp_kind="swiglu",
    long_context_mode="swa",
)
