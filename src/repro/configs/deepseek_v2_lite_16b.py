"""DeepSeek-V2-Lite-16B [arXiv:2405.04434] — MoE with MLA: kv_lora 512
compressed latent cache, 64 routed experts top-6 + 2 shared, per-expert
d_ff 1408. (The assignment note's "160 routed" is the full V2; Lite per
the paper is 64 routed — we follow the 64e top-6 numbers given.)"""
from repro.types import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    attn_kind="mla",
    q_lora_rank=0,               # V2-Lite: no query compression
    kv_lora_rank=512,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
    num_experts=64,
    experts_per_token=6,
    moe_d_ff=1408,
    num_shared_experts=2,
    rope_kind="rope",
    mlp_kind="swiglu",
    long_context_mode="swa",
)
