"""Architecture registry: --arch <id> -> ModelConfig."""
from __future__ import annotations

from repro.configs import (chatglm3_6b, deepseek_67b, deepseek_v2_lite_16b,
                           granite_moe_1b_a400m, minicpm3_4b, qwen2_vl_7b,
                           tinyllama_1_1b, whisper_small, xlstm_125m,
                           zamba2_1_2b)
from repro.configs.shapes import SHAPES
from repro.types import ModelConfig, ShapeConfig

ARCHS = {
    c.CONFIG.name: c.CONFIG
    for c in (chatglm3_6b, deepseek_67b, qwen2_vl_7b, granite_moe_1b_a400m,
              xlstm_125m, tinyllama_1_1b, zamba2_1_2b, deepseek_v2_lite_16b,
              whisper_small, minicpm3_4b)
}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; available: {sorted(SHAPES)}")
    return SHAPES[name]
