"""xLSTM-125M [arXiv:2405.04517] — sLSTM + mLSTM blocks (1 sLSTM per 6),
4 heads, d_ff=0 (blocks carry their own projections). Attention-free:
long_500k runs natively (O(1) recurrent state)."""
from repro.types import ModelConfig

_PATTERN = tuple("slstm" if i % 6 == 3 else "mlstm" for i in range(12))

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_pattern=_PATTERN,
    ssm_conv=4,
    ssm_chunk=256,
    rope_kind="none",
    long_context_mode="native",
)
