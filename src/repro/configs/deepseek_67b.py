"""DeepSeek-67B [arXiv:2401.02954] — dense llama-arch, 95 layers, GQA kv=8.
Largest assigned model: FSDP param sharding over the data axis."""
from repro.types import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    family="dense",
    num_layers=95,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=102400,
    rope_kind="rope",
    mlp_kind="swiglu",
    fsdp=True,
    long_context_mode="swa",
)
