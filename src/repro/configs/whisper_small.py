"""Whisper-small [arXiv:2212.04356] — encoder-decoder, 12+12 layers, MHA,
GELU MLP, LayerNorm, sinusoidal positions. Mel + conv frontend is the allowed
STUB: input_specs provides (B, 1500, d_model) frame embeddings.
long_500k SKIPPED: the 30 s audio frontend bounds the decode regime
(decoder max positions ≈ 448); see DESIGN.md shape/skip matrix."""
from repro.types import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    is_encoder_decoder=True,
    num_encoder_layers=12,
    encoder_seq=1500,
    rope_kind="none",
    norm_kind="layernorm",
    mlp_kind="gelu",
    long_context_mode="skip",
)
