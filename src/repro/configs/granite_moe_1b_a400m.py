"""Granite-3.0-1B-A400M [hf:ibm-granite/granite-3.0-1b-a400m-base] — MoE,
32 experts top-8, expert d_ff 512 (d_ff column of the assignment = per-expert
ffn width), GQA kv=8."""
from repro.types import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    num_experts=32,
    experts_per_token=8,
    moe_d_ff=512,
    rope_kind="rope",
    mlp_kind="swiglu",
    long_context_mode="swa",
)
