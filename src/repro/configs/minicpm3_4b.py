"""MiniCPM3-4B [hf:openbmb/MiniCPM3-4B] — dense with MLA (q_lora 768,
kv_lora 256, qk_nope 64 + qk_rope 32, v_head 64), 62 layers, 40 heads."""
from repro.types import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    attn_kind="mla",
    q_lora_rank=768,
    kv_lora_rank=256,
    qk_rope_dim=32,
    qk_nope_dim=64,
    v_head_dim=64,
    head_dim=64,
    rope_kind="rope",
    mlp_kind="swiglu",
    long_context_mode="swa",
)
