"""Core configuration dataclasses for the repro framework.

ModelConfig covers every assigned architecture family (dense / moe / ssm /
hybrid / vlm / audio) with a single spine; ShapeConfig describes the assigned
input shapes; TrainConfig / CollabConfig parameterize the paper's technique
(CoRS: Collaborative Representation Sharing).
"""
from __future__ import annotations

import dataclasses
import warnings
from dataclasses import dataclass
from typing import Any, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # --- attention ---
    attn_kind: str = "gqa"           # gqa | mla
    rope_kind: str = "rope"          # rope | rope2d | mrope | none
    rope_theta: float = 10000.0
    sliding_window: int = 0          # 0 = full attention (training-time SWA)

    # --- MLA (deepseek-v2 / minicpm3) ---
    q_lora_rank: int = 0             # 0 -> full-rank queries
    kv_lora_rank: int = 0
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 0              # 0 -> head_dim

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    num_shared_experts: int = 0
    capacity_factor: float = 1.5
    router_aux_weight: float = 0.01

    # --- SSM / hybrid / xlstm ---
    block_pattern: Tuple[str, ...] = ()   # per-layer kinds; () -> all "attn"
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_heads: int = 0               # mamba2 heads; 0 -> d_inner // 64
    shared_attn_period: int = 0      # zamba2: shared attn block every k layers
    ssm_chunk: int = 256             # SSD chunk length

    # --- encoder-decoder (whisper) ---
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq: int = 1500          # frames after the conv stub

    # --- misc ---
    norm_eps: float = 1e-5
    norm_kind: str = "rmsnorm"       # rmsnorm | layernorm
    mlp_kind: str = "swiglu"         # swiglu | gelu
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    input_kind: str = "tokens"       # tokens | embeddings (vlm/audio stubs)

    # --- CoRS (the paper) ---
    d_feature: int = 0               # d' (0 -> d_model): last-hidden width

    # --- sharding hints ---
    fsdp: bool = False               # shard params over data axis too
    long_context_mode: str = "swa"   # swa | native | skip  (for long_500k)
    swa_window: int = 8192           # window used by the long_500k swa variant

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.v_head_dim == 0:
            object.__setattr__(self, "v_head_dim", self.head_dim)
        if self.d_feature == 0:
            object.__setattr__(self, "d_feature", self.d_model)
        if not self.block_pattern:
            object.__setattr__(
                self, "block_pattern", tuple(["attn"] * self.num_layers))
        assert len(self.block_pattern) == self.num_layers, (
            self.name, len(self.block_pattern), self.num_layers)

    # ------------------------------------------------------------------
    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def mamba_heads(self) -> int:
        return self.ssm_heads or max(1, self.d_inner // 64)

    @property
    def mamba_head_dim(self) -> int:
        return self.d_inner // self.mamba_heads

    @property
    def is_mla(self) -> bool:
        return self.attn_kind == "mla"

    @property
    def qk_head_dim(self) -> int:
        if self.is_mla:
            return self.qk_nope_dim + self.qk_rope_dim
        return self.head_dim

    def reduced(self, *, num_layers: int = 2, d_model: int = 256,
                vocab_size: int = 512, num_experts: int = 0,
                seq_cap: int = 0) -> "ModelConfig":
        """A tiny same-family variant for CPU smoke tests."""
        heads = min(self.num_heads, 4)
        kv = min(self.num_kv_heads, heads)
        while heads % kv:
            kv -= 1
        hd = max(16, d_model // heads)
        d_model = hd * heads
        n_exp = num_experts or (min(self.num_experts, 4) if self.num_experts else 0)
        pattern = _reduced_pattern(self.block_pattern, num_layers)
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=num_layers,
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=hd,
            v_head_dim=0,
            d_ff=max(4 * hd, 64) if self.d_ff else 0,
            vocab_size=vocab_size,
            num_experts=n_exp,
            experts_per_token=min(self.experts_per_token, max(n_exp // 2, 1)) if n_exp else 0,
            moe_d_ff=64 if n_exp else 0,
            num_shared_experts=min(self.num_shared_experts, 1),
            q_lora_rank=32 if self.q_lora_rank else 0,
            kv_lora_rank=32 if self.kv_lora_rank else 0,
            qk_rope_dim=16 if self.is_mla else self.qk_rope_dim,
            qk_nope_dim=hd if self.is_mla else self.qk_nope_dim,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_heads=4 if self.ssm_state else 0,
            ssm_chunk=16,
            shared_attn_period=2 if self.shared_attn_period else 0,
            num_encoder_layers=2 if self.is_encoder_decoder else 0,
            encoder_seq=8 if self.is_encoder_decoder else self.encoder_seq,
            block_pattern=pattern,
            d_feature=0,
            dtype="float32",
            fsdp=False,
        )


def _reduced_pattern(pattern: Tuple[str, ...], n: int) -> Tuple[str, ...]:
    kinds = []
    seen = []
    for k in pattern:
        if k not in seen:
            seen.append(k)
    # keep one layer of each distinct kind, cycling, up to n layers
    for i in range(n):
        kinds.append(seen[i % len(seen)])
    return tuple(kinds)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                        # train | prefill | decode


@dataclass(frozen=True)
class CollabConfig:
    """Hyper-parameters of the paper's technique (CoRS)."""
    lambda_kd: float = 10.0          # paper Fig.3 chosen value
    lambda_disc: float = 1.0
    n_avg: int = 10                  # samples per observation average
    m_up: int = 1                    # observations uploaded per class/round
    m_down: int = 1                  # observations downloaded per class/round
    num_classes: int = 10
    d_feature: int = 84
    num_negatives: int = 0           # 0 -> K = C-1 (paper); >0 -> sampled (LM)
    proto_momentum: float = 0.0      # 0 = per-round recompute (paper); >0 EMA
    mode: str = "cors"               # cors | il | fedavg | fd | cl


@dataclass(frozen=True)
class FleetConfig:
    """Who the fleet is and how it behaves — everything about a client
    population that is NOT a training hyper-parameter: the relay policy,
    the participation schedule, the upload/download clock models and the
    device mesh. One object accepted by BOTH engines (`fleet=`), replacing
    the former loose `policy= / schedule= / clock= / download_clock= /
    mesh=` trainer kwargs (still accepted for one release through a
    `DeprecationWarning` shim, `resolve_fleet`).

    Fields hold either spec strings (parsed by the engines through
    `repro.specs.parse_spec` — e.g. policy="staleness:0.5",
    participation="uniform_k:8", clock="lognormal:4") or already-built
    objects (RelayPolicy / ParticipationSchedule / ClockModel / Mesh);
    `Any`-typed so this module stays import-light (no jax dependency)."""
    policy: Any = None                  # relay policy spec | RelayPolicy
    participation: Any = None           # schedule spec | ParticipationSchedule
    clock: Any = None                   # upload ClockModel spec | instance
    download_clock: Any = None          # download ClockModel spec | instance
    mesh: Any = None                    # jax Mesh with a client axis, or None
    arrivals: Any = None                # streaming-population spec | instance


def resolve_fleet(fleet=None, **legacy) -> FleetConfig:
    """The one-release deprecation shim for the pre-FleetConfig trainer
    kwargs: fold non-None legacy kwargs (`policy`, `schedule`, `clock`,
    `download_clock`, `mesh`) into a FleetConfig, warning once per call
    site. Mixing `fleet=` with legacy kwargs is an error — two sources of
    truth for the same field is exactly the bug FleetConfig removes."""
    used = {k: v for k, v in legacy.items() if v is not None}
    if not used:
        return fleet if fleet is not None else FleetConfig()
    if fleet is not None:
        raise ValueError(
            f"pass fleet=FleetConfig(...) OR legacy kwargs, not both; got "
            f"fleet and {sorted(used)}")
    warnings.warn(
        f"repro: trainer kwargs {sorted(used)} are deprecated; pass "
        "fleet=FleetConfig(policy=..., participation=..., clock=..., "
        "download_clock=..., mesh=...) instead",
        DeprecationWarning, stacklevel=3)
    return FleetConfig(
        policy=used.get("policy"),
        participation=used.get("participation", used.get("schedule")),
        clock=used.get("clock"),
        download_clock=used.get("download_clock"),
        mesh=used.get("mesh"))


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 1e-3      # paper default
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 0.0
    batch_size: int = 32
    local_epochs: int = 1            # E in Algorithm 2
    rounds: int = 20
    seed: int = 0
    optimizer: str = "adam"
    warmup_steps: int = 0
    schedule: str = "constant"       # constant | cosine
