"""LOCALUPDATE (paper Algorithm 2), model-agnostic.

A client is (apply, head): `apply(params, x) -> (features, logits)` and
`head(params) -> (W, b)` exposing the linear classifier τ_u used by the
discriminator. Works for the paper's CNNs and for LM adapters alike.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import losses, prototypes
from repro.optim import adam_update
from repro.types import CollabConfig, TrainConfig


@dataclass(frozen=True)
class ClientSpec:
    apply: Callable  # (params, x) -> (features (B,d'), logits (B,C))
    head: Callable   # params -> (W (d',C), b (C,) | None)


def bucket_key(spec: ClientSpec, params) -> Tuple:
    """Stackability key of one client: clients can share a vmapped round
    step iff they share BOTH the ClientSpec (same apply/head callables) and
    the exact param pytree structure + leaf shapes/dtypes. Two clients with
    the same spec but e.g. different hidden widths land in different
    buckets — their param stacks cannot be concatenated."""
    leaves, treedef = jax.tree.flatten(params)
    return (spec, treedef,
            tuple((tuple(leaf.shape), str(leaf.dtype)) for leaf in leaves))


def bucketize(specs: Sequence[ClientSpec],
              params_list: Sequence) -> List[Tuple[ClientSpec, List[int]]]:
    """Group clients into stackable buckets: (spec, client-id list) pairs in
    FIRST-APPEARANCE order, client-id order within a bucket.

    This ordering is load-bearing: it is the order in which the bucketed
    vectorized engine (core/vec_collab.py) appends each bucket's uploads to
    the shared relay, and the sequential oracle (core/collab.py) uploads in
    the same order so the two engines evolve identical ring state. For a
    homogeneous fleet there is one bucket and the order degenerates to plain
    client-id order — bit-compatible with the pre-bucketing engines.

    Distinct-but-identical ClientSpec objects (e.g. two lambdas with the
    same body) intentionally hash apart: callers that want clients stacked
    together must share ONE spec object across them, which is also what
    makes the per-spec jit caches effective."""
    assert len(specs) == len(params_list)
    buckets: Dict[Tuple, List[int]] = {}
    order: List[Tuple] = []
    for i, (s, p) in enumerate(zip(specs, params_list)):
        k = bucket_key(s, p)
        if k not in buckets:
            buckets[k] = []
            order.append(k)
        buckets[k].append(i)
    return [(k[0], buckets[k]) for k in order]


def loss_fn(spec: ClientSpec, params, batch, teacher, ccfg: CollabConfig,
            key=None):
    """One mini-batch of Algorithm 2's inner loop.

    teacher: dict(global_protos (C,d'), valid_g (C,), obs (M,C,d'),
    valid_o (C,), obs_pick (int32 scalar: which m to use)) — or None entries
    for IL/CL/FD modes.
    """
    x, y = batch["x"], batch["y"]
    feats, logits = spec.apply(params, x)
    l_ce = losses.ce_loss(logits, y)
    metrics = {"ce": l_ce}
    total = l_ce
    if ccfg.mode == "cors":
        w, b = spec.head(params)
        l_kd = losses.kd_loss(feats, teacher["global_protos"], y,
                              valid=teacher["valid_g"])
        m = teacher.get("obs_pick", 0)
        obs_m = teacher["obs"][m]                            # (C, d')
        l_disc = losses.disc_loss(feats, obs_m, y, w, b,
                                  valid=teacher["valid_o"],
                                  student_logits=logits)
        total = total + ccfg.lambda_kd * l_kd + ccfg.lambda_disc * l_disc
        metrics.update(kd=l_kd, disc=l_disc,
                       mi_bound=losses.mi_lower_bound(
                           l_disc, ccfg.num_classes - 1))
    elif ccfg.mode == "fd":
        l_fd = losses.fd_loss(logits, teacher["mean_logits"], y,
                              valid=teacher["valid_g"])
        total = total + ccfg.lambda_kd * l_fd
        metrics["fd"] = l_fd
    metrics["total"] = total
    return total, metrics


def empty_teacher(ccfg: CollabConfig) -> Dict:
    """A no-op teacher pytree (IL/CL/FedAvg modes, round-0 defaults).

    Same keys/shapes as `server.sample_teacher` so the jitted update traces
    once regardless of mode."""
    C, d = ccfg.num_classes, ccfg.d_feature
    return {"global_protos": jnp.zeros((C, d), jnp.float32),
            "valid_g": jnp.zeros((C,), bool),
            "obs": jnp.zeros((max(1, ccfg.m_down), C, d), jnp.float32),
            "valid_o": jnp.zeros((C,), bool),
            "obs_pick": jnp.asarray(0, jnp.int32),
            "mean_logits": jnp.zeros((C, C), jnp.float32)}


def make_local_update_fn(spec: ClientSpec, ccfg: CollabConfig,
                         tcfg: TrainConfig):
    """Un-jitted fn(params, opt_state, batches, teacher, key) ->
    (params, opt_state, metrics). `batches` is a stacked pytree
    (n_batches, bs, ...) scanned E local epochs (Algorithm 2).

    The sequential trainer jits this per client (`make_local_update`); the
    vectorized engine vmaps it over a stacked client axis inside one jitted
    round step (core/vec_collab.py)."""

    grad_fn = jax.value_and_grad(
        lambda p, b, t, k: loss_fn(spec, p, b, t, ccfg, k), has_aux=True)

    def run(params, opt_state, batches, teacher, key):
        n = jax.tree.leaves(batches)[0].shape[0]
        keys = jax.random.split(key, n * tcfg.local_epochs).reshape(
            tcfg.local_epochs, n, 2)

        def step(carry, batch_and_key):
            p, o = carry
            batch, k = batch_and_key
            (_, metrics), grads = grad_fn(p, batch, teacher, k)
            # global grad norm, from the grads the step already computed —
            # the per-bucket health signal the telemetry layer aggregates
            metrics["grad_norm"] = jnp.sqrt(sum(
                jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads)))
            p, o = adam_update(p, grads, o, lr=tcfg.learning_rate,
                               b1=tcfg.beta1, b2=tcfg.beta2, eps=tcfg.eps)
            return (p, o), metrics

        def epoch(carry, ek):
            return jax.lax.scan(step, carry, (batches, ek))

        (params, opt_state), metrics = jax.lax.scan(
            epoch, (params, opt_state), keys)
        metrics = jax.tree.map(lambda m: m[-1, -1], metrics)  # last batch
        return params, opt_state, metrics

    return run


def make_local_update(spec: ClientSpec, ccfg: CollabConfig,
                      tcfg: TrainConfig):
    """Jitted `make_local_update_fn` (the per-client sequential path)."""
    return jax.jit(make_local_update_fn(spec, ccfg, tcfg))


def zero_metrics(ccfg: CollabConfig) -> Dict:
    """The metrics record of a client that SKIPPED the round (partial
    participation): all-zero floats with exactly the keys `loss_fn` emits
    for this mode, so per-round records keep one entry per client."""
    m = {"ce": 0.0, "total": 0.0, "grad_norm": 0.0}
    if ccfg.mode == "cors":
        m.update(kd=0.0, disc=0.0, mi_bound=0.0)
    elif ccfg.mode == "fd":
        m["fd"] = 0.0
    return m


def compute_uploads(spec: ClientSpec, params, data_x, data_y,
                    ccfg: CollabConfig, key):
    """End-of-round uploads (Algorithm 1): the client's per-class averaged
    representations (for t̄) and M_↑ observations (for the L_disc buffers).
    For FD mode, per-class mean logits instead."""
    feats, logits = spec.apply(params, data_x)
    state = prototypes.accumulate(
        prototypes.init_state(ccfg.num_classes, feats.shape[-1]),
        feats, data_y)
    obs, valid = prototypes.observations(key, feats, data_y,
                                         ccfg.num_classes, ccfg.n_avg,
                                         ccfg.m_up)
    out = {"proto": state, "obs": obs, "valid": valid}
    if ccfg.mode == "fd":
        lstate = prototypes.accumulate(
            prototypes.init_state(ccfg.num_classes, logits.shape[-1]),
            logits, data_y)
        out["logit_proto"] = lstate
    return out


def make_compute_uploads(spec: ClientSpec, ccfg: CollabConfig):
    """Jitted `compute_uploads` with spec/ccfg closed over (they are static
    config, not data). The sequential trainer caches ONE of these per
    distinct ClientSpec: the eager version cost ~20 ms/client/round of pure
    dispatch, dominant at small per-client data; jitted it traces once per
    data shape and never again (tests assert the cache stays at one entry
    across rounds)."""
    return jax.jit(lambda params, x, y, key: compute_uploads(
        spec, params, x, y, ccfg, key))
