"""Exact communication accounting (paper §Communication).

Per round and per client, in floats (×4 bytes fp32 on the wire):
  CoRS uplink   : (M_↑ + 1)·C·d'        (observations + averaged reps)
  CoRS downlink : (M_↓ + 1)·C·d'        (observations + global prototypes)
  FD            : C·C each way           (mean logits)
  FedAvg        : D each way             (the whole model)
  SL            : n·d' up per epoch      (per-sample smashed data), for the
                  paper's O() comparison only.
"""
from __future__ import annotations

from dataclasses import dataclass, field

BYTES = 4


@dataclass
class CommLedger:
    up_floats: float = 0.0
    down_floats: float = 0.0
    by_round: list = field(default_factory=list)

    def log_round(self, up: float, down: float):
        self.up_floats += up
        self.down_floats += down
        self.by_round.append((up, down))

    @property
    def total_bytes(self) -> float:
        return BYTES * (self.up_floats + self.down_floats)


def cors_round_floats(C: int, d: int, m_up: int, m_down: int, n_clients: int):
    up = n_clients * (m_up + 1) * C * d
    down = n_clients * (m_down + 1) * C * d
    return up, down


def fd_round_floats(C: int, n_clients: int):
    return n_clients * C * C, n_clients * C * C


def fedavg_round_floats(model_size: int, n_clients: int):
    return n_clients * model_size, n_clients * model_size


def sl_epoch_floats(n_samples: int, d: int, n_clients: int):
    return n_clients * n_samples * d, n_clients * n_samples * d


def round_floats(mode: str, *, n_present: int, C: int = 0, d: int = 0,
                 m_up: int = 0, m_down: int = 0, model_size: int = 0,
                 n_commit=None, n_read=None):
    """Per-round (up, down) floats for any mode, billing only the clients
    that actually exchanged bytes this round. Shared by both engines so
    their ledgers agree bit-for-bit.

    Async billing (relay/events.py): an upload crosses the wire when it
    COMMITS, a download when the client READS — so uplink floats are
    billed to the commit round (`n_commit` uploads arrived this round,
    possibly born rounds ago) and downlink floats to the read round
    (`n_read` clients fetched a snapshot this round). Under download lag
    (relay/history.py) the snapshot a client reads may be rounds STALE,
    but the bytes still cross the wire at read time, so `n_read` equals
    the round's present-client count and total downlink is invariant
    under any download-delay map — the conservation law the property
    tests pin. n_commit / n_read None mean the synchronous fleet, where
    commit, read and sync rounds all coincide."""
    if n_commit is None:
        n_commit = n_present
    if n_read is None:
        n_read = n_present
    if mode == "fedavg":
        return fedavg_round_floats(model_size, n_present)
    if mode == "cors":
        up, _ = cors_round_floats(C, d, m_up, m_down, n_commit)
        _, down = cors_round_floats(C, d, m_up, m_down, n_read)
        return up, down
    if mode == "fd":
        up, _ = fd_round_floats(C, n_commit)
        _, down = fd_round_floats(C, n_read)
        return up, down
    return 0.0, 0.0
