"""Multi-client simulation trainer — the paper's experimental harness.

Runs CoRS and all baselines (CL / IL / FD / FedAvg) with identical data
partitions, optimizers and round accounting, so benchmarks/table1_utility.py
reproduces the paper's Table 1 comparison semantics. Clients may have
heterogeneous architectures in CoRS/FD modes (a selling point of the paper);
FedAvg requires homogeneous models and asserts so.

This sequential trainer is the ORACLE: it steps clients one-by-one and is
the only path that supports heterogeneous client architectures. Rounds are
synchronous (paper Algorithm 1 cadence): every client downloads from the
relay state of the PREVIOUS round, then all upload — so the vectorized
engine (core/vec_collab.py), which runs all clients in one vmapped step,
evolves the exact same relay state given the same seeds (see
`round_keys` for the shared per-round key schedule).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines, client as client_lib, comm, server as server_lib
from repro.optim import adam_init
from repro.types import CollabConfig, TrainConfig


def round_keys(key, n: int):
    """Canonical per-round key schedule, shared with the vectorized engine:
    one relay, one update and one upload key per client, drawn from three
    independent folds of the round key. Returns (next_key, relay (n,2),
    update (n,2), upload (n,2))."""
    key, kr, ku, ko = jax.random.split(key, 4)
    return (key, jax.random.split(kr, n), jax.random.split(ku, n),
            jax.random.split(ko, n))


@dataclass
class ClientState:
    spec: client_lib.ClientSpec
    params: Any
    opt_state: Any
    data_x: jax.Array
    data_y: jax.Array


class CollabTrainer:
    def __init__(self, specs: Sequence[client_lib.ClientSpec],
                 params_list: Sequence[Any],
                 client_data: Sequence[Tuple[jax.Array, jax.Array]],
                 test_data: Tuple[jax.Array, jax.Array],
                 ccfg: CollabConfig, tcfg: TrainConfig, seed: int = 0):
        assert len(specs) == len(params_list) == len(client_data)
        self.ccfg, self.tcfg = ccfg, tcfg
        self.clients = [
            ClientState(spec=s, params=p, opt_state=adam_init(p),
                        data_x=x, data_y=y)
            for s, p, (x, y) in zip(specs, params_list, client_data)]
        self.test_x, self.test_y = test_data
        self.server = server_lib.RelayServer(ccfg, ccfg.d_feature, seed,
                                             n_clients=len(specs))
        self.ledger = comm.CommLedger()
        self.key = jax.random.PRNGKey(seed)
        self._updaters = [client_lib.make_local_update(c.spec, ccfg, tcfg)
                          for c in self.clients]
        # one jitted eval fn per distinct spec (not per call: re-jitting a
        # fresh lambda every evaluate() recompiled every round)
        self._eval_cache: Dict[client_lib.ClientSpec, Callable] = {}
        self.history: List[Dict] = []

    # ------------------------------------------------------------------
    def _batches(self, c: ClientState):
        bs = self.tcfg.batch_size
        n = (c.data_x.shape[0] // bs) * bs
        xs = c.data_x[:n].reshape(-1, bs, *c.data_x.shape[1:])
        ys = c.data_y[:n].reshape(-1, bs)
        return {"x": xs, "y": ys}

    # ------------------------------------------------------------------
    def run_round(self) -> Dict:
        ccfg = self.ccfg
        mode = ccfg.mode
        N = len(self.clients)
        self.key, relay_ks, upd_ks, upl_ks = round_keys(self.key, N)

        # phase 1 — downlink: every client sees last round's relay state
        if mode in ("cors", "fd"):
            teachers = [self.server.relay(i, max(1, ccfg.m_down), relay_ks[i])
                        for i in range(N)]
        else:
            teachers = [client_lib.empty_teacher(ccfg)] * N

        # phase 2 — local updates (Algorithm 2)
        metrics_all = []
        for i, c in enumerate(self.clients):
            c.params, c.opt_state, m = self._updaters[i](
                c.params, c.opt_state, self._batches(c), teachers[i],
                upd_ks[i])
            metrics_all.append(jax.tree.map(float, m))

        # phase 3 — uplink + server merge (Algorithm 1)
        if mode in ("cors", "fd"):
            self.server.begin_round()
            for i, c in enumerate(self.clients):
                payload = client_lib.compute_uploads(
                    c.spec, c.params, c.data_x, c.data_y, ccfg, upl_ks[i])
                self.server.upload(i, payload)
            self.server.end_round()

        if mode == "fedavg":
            avg = baselines.fedavg_aggregate([c.params for c in self.clients])
            for c in self.clients:
                c.params = avg
            up, down = comm.fedavg_round_floats(
                baselines.num_params(self.clients[0].params), N)
        elif mode == "cors":
            up, down = comm.cors_round_floats(
                ccfg.num_classes, ccfg.d_feature, ccfg.m_up, ccfg.m_down, N)
        elif mode == "fd":
            up, down = comm.fd_round_floats(ccfg.num_classes, N)
        else:
            up = down = 0.0
        self.ledger.log_round(up, down)

        accs = [self.evaluate(c) for c in self.clients]
        rec = {"round": len(self.history) + 1,
               "acc_mean": float(np.mean(accs)),
               "acc_std": float(np.std(accs)),
               "accs": accs,
               "metrics": metrics_all,
               "comm_up": up, "comm_down": down}
        self.history.append(rec)
        return rec

    def run(self, rounds: int, log_every: int = 0) -> List[Dict]:
        for r in range(rounds):
            rec = self.run_round()
            if log_every and (r + 1) % log_every == 0:
                print(f"  round {rec['round']:3d} acc {rec['acc_mean']:.4f}"
                      f" ±{rec['acc_std']:.4f}")
        return self.history

    # ------------------------------------------------------------------
    def _eval_fn(self, spec: client_lib.ClientSpec):
        fn = self._eval_cache.get(spec)
        if fn is None:
            fn = jax.jit(lambda p, x: spec.apply(p, x)[1])
            self._eval_cache[spec] = fn
        return fn

    def evaluate(self, c: ClientState, batch: int = 512) -> float:
        n = self.test_x.shape[0]
        correct = 0
        apply = self._eval_fn(c.spec)
        for i in range(0, n, batch):
            lg = apply(c.params, self.test_x[i:i + batch])
            correct += int(jnp.sum(jnp.argmax(lg, -1)
                                   == self.test_y[i:i + batch]))
        return correct / n
