"""Multi-client simulation trainer — the paper's experimental harness.

Runs CoRS and all baselines (CL / IL / FD / FedAvg) with identical data
partitions, optimizers and round accounting, so benchmarks/table1_utility.py
reproduces the paper's Table 1 comparison semantics. Clients may have
heterogeneous architectures in CoRS/FD modes (a selling point of the paper);
FedAvg requires homogeneous models and asserts so.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines, client as client_lib, comm, server as server_lib
from repro.optim import adam_init
from repro.types import CollabConfig, TrainConfig


@dataclass
class ClientState:
    spec: client_lib.ClientSpec
    params: Any
    opt_state: Any
    data_x: jax.Array
    data_y: jax.Array


class CollabTrainer:
    def __init__(self, specs: Sequence[client_lib.ClientSpec],
                 params_list: Sequence[Any],
                 client_data: Sequence[Tuple[jax.Array, jax.Array]],
                 test_data: Tuple[jax.Array, jax.Array],
                 ccfg: CollabConfig, tcfg: TrainConfig, seed: int = 0):
        assert len(specs) == len(params_list) == len(client_data)
        self.ccfg, self.tcfg = ccfg, tcfg
        self.clients = [
            ClientState(spec=s, params=p, opt_state=adam_init(p),
                        data_x=x, data_y=y)
            for s, p, (x, y) in zip(specs, params_list, client_data)]
        self.test_x, self.test_y = test_data
        self.server = server_lib.RelayServer(ccfg, ccfg.d_feature, seed)
        self.ledger = comm.CommLedger()
        self.key = jax.random.PRNGKey(seed)
        self._updaters = [client_lib.make_local_update(c.spec, ccfg, tcfg)
                          for c in self.clients]
        self.history: List[Dict] = []

    # ------------------------------------------------------------------
    def _batches(self, c: ClientState):
        bs = self.tcfg.batch_size
        n = (c.data_x.shape[0] // bs) * bs
        xs = c.data_x[:n].reshape(-1, bs, *c.data_x.shape[1:])
        ys = c.data_y[:n].reshape(-1, bs)
        return {"x": xs, "y": ys}

    def _nextkey(self):
        self.key, k = jax.random.split(self.key)
        return k

    def _empty_teacher(self):
        C, d = self.ccfg.num_classes, self.ccfg.d_feature
        return {"global_protos": jnp.zeros((C, d), jnp.float32),
                "valid_g": jnp.zeros((C,), bool),
                "obs": jnp.zeros((max(1, self.ccfg.m_down), C, d), jnp.float32),
                "valid_o": jnp.zeros((C,), bool),
                "obs_pick": jnp.asarray(0, jnp.int32),
                "mean_logits": jnp.zeros((C, C), jnp.float32)}

    # ------------------------------------------------------------------
    def run_round(self) -> Dict:
        ccfg = self.ccfg
        mode = ccfg.mode
        N = len(self.clients)
        self.server.begin_round()
        metrics_all = []
        for i, c in enumerate(self.clients):
            if mode in ("cors", "fd"):
                teacher = self.server.relay(i, max(1, ccfg.m_down),
                                            self._nextkey())
                t = self._empty_teacher()
                t.update(teacher)
                teacher = t
            else:
                teacher = self._empty_teacher()
            c.params, c.opt_state, m = self._updaters[i](
                c.params, c.opt_state, self._batches(c), teacher,
                self._nextkey())
            metrics_all.append(jax.tree.map(float, m))
            if mode in ("cors", "fd"):
                payload = client_lib.compute_uploads(
                    c.spec, c.params, c.data_x, c.data_y, ccfg,
                    self._nextkey())
                self.server.upload(i, payload)
        self.server.end_round()

        if mode == "fedavg":
            avg = baselines.fedavg_aggregate([c.params for c in self.clients])
            for c in self.clients:
                c.params = avg
            up, down = comm.fedavg_round_floats(
                baselines.num_params(self.clients[0].params), N)
        elif mode == "cors":
            up, down = comm.cors_round_floats(
                ccfg.num_classes, ccfg.d_feature, ccfg.m_up, ccfg.m_down, N)
        elif mode == "fd":
            up, down = comm.fd_round_floats(ccfg.num_classes, N)
        else:
            up = down = 0.0
        self.ledger.log_round(up, down)

        accs = [self.evaluate(c) for c in self.clients]
        rec = {"round": len(self.history) + 1,
               "acc_mean": float(np.mean(accs)),
               "acc_std": float(np.std(accs)),
               "accs": accs,
               "metrics": metrics_all,
               "comm_up": up, "comm_down": down}
        self.history.append(rec)
        return rec

    def run(self, rounds: int, log_every: int = 0) -> List[Dict]:
        for r in range(rounds):
            rec = self.run_round()
            if log_every and (r + 1) % log_every == 0:
                print(f"  round {rec['round']:3d} acc {rec['acc_mean']:.4f}"
                      f" ±{rec['acc_std']:.4f}")
        return self.history

    # ------------------------------------------------------------------
    def evaluate(self, c: ClientState, batch: int = 512) -> float:
        n = self.test_x.shape[0]
        correct = 0
        apply = jax.jit(lambda p, x: c.spec.apply(p, x)[1])
        for i in range(0, n, batch):
            lg = apply(c.params, self.test_x[i:i + batch])
            correct += int(jnp.sum(jnp.argmax(lg, -1)
                                   == self.test_y[i:i + batch]))
        return correct / n
