"""Multi-client simulation trainer — the paper's experimental harness.

Runs CoRS and all baselines (CL / IL / FD / FedAvg) with identical data
partitions, optimizers and round accounting, so benchmarks/table1_utility.py
reproduces the paper's Table 1 comparison semantics. Clients may have
heterogeneous architectures in CoRS/FD modes (a selling point of the paper);
FedAvg requires homogeneous models and asserts so.

This sequential trainer is the ORACLE: it steps clients one-by-one, for any
mix of client architectures. Rounds are synchronous (paper Algorithm 1
cadence): every client downloads from the relay state of the PREVIOUS
round, then all upload — so the vectorized engine (core/vec_collab.py),
which runs each spec-bucket of clients in one vmapped step, evolves the
exact same relay state given the same seeds (see `round_keys` for the
shared per-round key schedule).

Upload ordering: uploads happen in BUCKET order (client_lib.bucketize —
clients grouped by stackable (spec, param-shape) key in first-appearance
order, client-id order within a bucket), because that is the order in which
the bucketed engine writes each bucket's observation rows into the shared
relay ring. For a homogeneous fleet this degenerates to plain client-id
order, i.e. exactly the pre-bucketing behavior. Downloads are order-free
(every present client reads the same round-start state) and the per-client
key schedule is indexed by client id, so ordering changes nothing else.

Server behavior is pluggable via `policy` (a repro.relay RelayPolicy spec:
"flat" | "per_class" | "staleness") and `schedule` (a participation
schedule: "full" | "uniform_k:K" | "cyclic:K" | "bernoulli:P" |
"adaptive:P[,BOOST]"); absent clients are skipped entirely — no download,
no update, no upload, no comm billed — which is the reference semantics
the vectorized engine's masked client axis is tested against
(tests/test_relay_policies.py).

Asynchrony: pass `clock` (a repro.sim ClockModel spec, e.g.
"lognormal:4") and uploads commit LATE — a round-r upload with commit
delay d is parked in the event queue and appended in round r+d, in event
order (birth round, then upload position; see relay/events.py). This
trainer is the EVENT-REPLAY ORACLE: it replays the identical commit order
the vectorized engine's pending buffer produces, one host-side event at a
time, and therefore stays the bit-exact ring/stamp bookkeeping reference
under any clock model. A client's teachers always come from the committed
state at its sync (round start) — in-flight uploads are invisible, which
is exactly what distinguishes the relay from SplitFed's synchronous
server. `clock=None` (or D_max=0) is today's synchronous behavior,
bit-identical.

Download lag: pass `download_clock` (same `repro.sim` spec machinery,
independent seed fold) and a client training in round t reads its teachers
AND global prototypes from a snapshot `d(client, t)` rounds STALER than
its round-start sync — the state its round-`t − d` self would have read
fresh, i.e. the post-merge state of round `t − d − 1` (d = 0 is the
round-start state itself) — the stale-sync half that the event log's late
uploads don't model. This trainer keeps the last
`H_max = d_max + 1` post-merge states in a host-side most-recent-first
list, the exact replay of the vectorized engine's relay/history.py ring
(every ring slot starts as the init state, so early deep reads see the
Algorithm-1 init in both engines). Downlink is billed at READ — the bytes
cross the wire when the snapshot is served, however stale it is — so the
ledger is invariant under the delay map. `download_clock=None` (or
d_max=0, delay 0 everywhere) is today's round-fresh download,
bit-identical.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs, relay as relay_lib, sim
from repro.core import baselines, client as client_lib, comm
from repro.optim import adam_init
from repro.relay import events
from repro.types import CollabConfig, TrainConfig, resolve_fleet


def round_keys(key, n: int):
    """Canonical per-round key schedule, shared with the vectorized engine:
    one relay, one update and one upload key per client, drawn from three
    independent folds of the round key. Returns (next_key, relay (n,2),
    update (n,2), upload (n,2))."""
    key, kr, ku, ko = jax.random.split(key, 4)
    return (key, jax.random.split(kr, n), jax.random.split(ku, n),
            jax.random.split(ko, n))


@dataclass
class ClientState:
    spec: client_lib.ClientSpec
    params: Any
    opt_state: Any
    data_x: jax.Array
    data_y: jax.Array


class CollabTrainer:
    def __init__(self, specs: Sequence[client_lib.ClientSpec],
                 params_list: Sequence[Any],
                 client_data: Sequence[Tuple[jax.Array, jax.Array]],
                 test_data: Tuple[jax.Array, jax.Array],
                 ccfg: CollabConfig, tcfg: TrainConfig, seed: int = 0,
                 fleet=None, policy=None, schedule=None, clock=None,
                 download_clock=None, telemetry=None):
        fleet = resolve_fleet(fleet, policy=policy, schedule=schedule,
                              clock=clock, download_clock=download_clock)
        if fleet.mesh is not None:
            raise ValueError(
                "the sequential oracle steps clients host-side and holds "
                "no stacked client axis to shard; FleetConfig.mesh only "
                "applies to the vectorized engine (core/vec_collab.py)")
        assert len(specs) == len(params_list) == len(client_data)
        self.ccfg, self.tcfg = ccfg, tcfg
        self.clients = [
            ClientState(spec=s, params=p, opt_state=adam_init(p),
                        data_x=x, data_y=y)
            for s, p, (x, y) in zip(specs, params_list, client_data)]
        self.test_x, self.test_y = test_data
        # Relay-write order shared with the bucketed vectorized engine:
        # bucket by bucket, client-id order within a bucket (identity for
        # homogeneous fleets). See the module docstring.
        buckets = client_lib.bucketize(specs, params_list)
        self._upload_order = [i for _, ids in buckets for i in ids]
        # Telemetry (repro.obs): the oracle computes the SAME jitted
        # telemetry function over its bit-equal ring state, so run_matched
        # can pin the integer leaves across engines; the event-log
        # quantities it already tracks host-side (commit lags, queue depth)
        # go in as small arrays.
        self.telemetry = obs.resolve(telemetry)
        self._telem = self.telemetry is not None and self.telemetry.metrics
        self._bucket_ids = [np.asarray(ids, np.int64) for _, ids in buckets]
        self._telem_fn = (obs.metrics.make_host_telemetry_fn(len(specs))
                          if self._telem else None)
        self._sink = (obs.JsonlWriter(self.telemetry.jsonl)
                      if self.telemetry and self.telemetry.jsonl else None)
        self._tracer = (obs.TraceRecorder(path=self.telemetry.trace,
                                          profile=self.telemetry.profile)
                        if self.telemetry and (self.telemetry.trace
                                               or self.telemetry.profile)
                        else None)
        self._span = self._tracer.span if self._tracer else obs.null_span
        self.clock = sim.get_clock(fleet.clock, seed=seed)
        self._queue = events.HostEventQueue()
        self.policy = relay_lib.get_policy(fleet.policy)
        # Streaming population (repro.sim.population): the cohort table
        # OWNS participation, ring rows are tagged with EXTERNAL ids, and
        # the table's LRU evictions hit the relay at round start. The
        # compositions below are rejected, not silently wrong: the async
        # pending buffer and the history ring key state by a STATIC id
        # space (upload position / snapshot owner), which seat turnover
        # invalidates — re-filed as ROADMAP follow-ons.
        self.arrivals = sim.get_arrivals(fleet.arrivals)
        self._streaming = self.arrivals is not None
        if self._streaming:
            if fleet.participation is not None:
                raise ValueError(
                    "streaming arrivals own participation (the cohort "
                    "table picks k active seats per round); leave "
                    "FleetConfig.participation unset")
            if self.clock is not None and self.clock.d_max > 0:
                raise ValueError(
                    "streaming arrivals do not compose with an async "
                    "upload clock yet: the pending buffer is indexed by "
                    "upload position, which seat turnover reuses")
            if fleet.download_clock is not None:
                raise ValueError(
                    "streaming arrivals do not compose with download lag "
                    "yet: history snapshots hold evicted owners' rows")
            if ccfg.mode not in ("cors", "fd"):
                raise ValueError(
                    "streaming arrivals need a relay mode (cors | fd); "
                    f"mode={ccfg.mode!r} has no server to stream through")
            if len(buckets) > 1:
                raise ValueError(
                    "streaming arrivals currently require a homogeneous "
                    "fleet (seats are interchangeable); got "
                    f"{len(buckets)} client buckets")
            self._cohort = self.arrivals.table(len(specs))
            self.schedule = None
        else:
            self._cohort = None
            self.schedule = relay_lib.get_schedule(fleet.participation,
                                                   seed=seed,
                                                   clock=self.clock)
        self.server = relay_lib.RelayServer(ccfg, ccfg.d_feature, seed,
                                            n_clients=len(specs),
                                            policy=self.policy)
        # Download lag (relay/history.py semantics, replayed host-side):
        # `_snaps` is the bounded most-recent-first ring of post-merge
        # relay states; a round-t client with download delay d reads
        # _snaps[d] = the state as of round t − d. Only relay modes
        # download, so only they carry the ring.
        self.dl_clock = sim.get_download_clock(fleet.download_clock, seed=seed)
        self._lagged = (self.dl_clock is not None
                        and ccfg.mode in ("cors", "fd"))
        self._h_max = (self.dl_clock.d_max + 1) if self._lagged else 1
        self._snaps = [self.server.state] if self._lagged else None
        self.ledger = comm.CommLedger()
        self.key = jax.random.PRNGKey(seed)
        self._updaters = [client_lib.make_local_update(c.spec, ccfg, tcfg)
                          for c in self.clients]
        # one jitted fn per distinct spec, NOT per call/round: re-jitting a
        # fresh lambda each time recompiled every round, and the eager
        # compute_uploads paid ~20 ms dispatch per client per round.
        self._eval_cache: Dict[client_lib.ClientSpec, Callable] = {}
        self._upload_cache: Dict[client_lib.ClientSpec, Callable] = {}
        self.history: List[Dict] = []

    # ------------------------------------------------------------------
    def _batches(self, c: ClientState):
        bs = self.tcfg.batch_size
        n = (c.data_x.shape[0] // bs) * bs
        xs = c.data_x[:n].reshape(-1, bs, *c.data_x.shape[1:])
        ys = c.data_y[:n].reshape(-1, bs)
        return {"x": xs, "y": ys}

    # ------------------------------------------------------------------
    def run_round(self) -> Dict:
        ccfg = self.ccfg
        mode = ccfg.mode
        N = len(self.clients)
        # Keys are drawn for ALL N clients regardless of participation, so
        # present clients consume the same per-client keys under every
        # schedule (and as in the vectorized engine); absent clients simply
        # never use theirs.
        r = len(self.history)
        self.key, relay_ks, upd_ks, upl_ks = round_keys(self.key, N)
        if self._streaming:
            # Cohort table view: participation mask over SEATS, external
            # ids per seat, and the owners LRU-evicted at admission time —
            # their ring slots are invalidated BEFORE any read this round.
            view = self._cohort.round(r)
            mask = view.mask.copy()
            ext_ids = view.seat_ids
            if view.evicted.size:
                with self._span("evict", round=r) as sp:
                    self.server.state = self.policy.evict_owners(
                        self.server.state,
                        jnp.asarray(view.evicted, jnp.int32))
                    sp.block(self.server.state)
        else:
            mask = np.asarray(self.schedule.mask(r, N), bool)
            ext_ids = None
        present = np.nonzero(mask)[0]
        # Ring owner tags use the EXTERNAL id under streaming arrivals;
        # seat index i doubles as the id for a static fleet.
        owner_of = ((lambda i: int(ext_ids[i])) if self._streaming
                    else (lambda i: int(i)))
        delays = (self.clock.delays(r, N) if self.clock is not None
                  else np.zeros((N,), np.int64))

        # phase 1 — downlink: every PRESENT client sees last round's state,
        # or — under a download clock — the post-merge snapshot from
        # d(client, r) rounds before that (its last completed sync).
        dl = (self.dl_clock.delays(r, N) if self._lagged
              else np.zeros((N,), np.int64))
        prev_state = self.server.state
        teachers: Dict[int, Dict] = {}
        with self._span("teacher_read", round=r) as sp:
            for i in present:
                teachers[i] = (self.server.relay(
                    owner_of(i), max(1, ccfg.m_down), relay_ks[i],
                    state=self._snapshot(int(dl[i])))
                    if mode in ("cors", "fd")
                    else client_lib.empty_teacher(ccfg))
            sp.block(teachers)

        # phase 2 — local updates (Algorithm 2); absent clients are frozen
        metrics_all = [jax.tree.map(float, client_lib.zero_metrics(ccfg))
                       for _ in range(N)]
        with self._span("update", round=r) as sp:
            for i in present:
                c = self.clients[i]
                c.params, c.opt_state, m = self._updaters[i](
                    c.params, c.opt_state, self._batches(c), teachers[i],
                    upd_ks[i])
                metrics_all[i] = jax.tree.map(float, m)
            sp.block([c.params for c in self.clients])

        # phase 3 — uplink + server merge (Algorithm 1). Present clients'
        # fresh uploads enter the event queue with their clock-model commit
        # delay; the relay then commits round r's DUE events in event order
        # (birth round, upload position — relay/events.py), each stamped
        # with its birth clock. With no clock (or D_max=0) every upload is
        # due at birth and this replays today's synchronous upload loop
        # bit-for-bit. A round with zero commits leaves the relay state
        # untouched (no merge, no clock tick).
        commits: List[Tuple[int, int]] = [(r, int(i)) for i in present]
        if mode in ("cors", "fd"):
            # Birth stamps are policy-resolved: the flat clock for single
            # relays (identical to the old int(state.clock) broadcast), the
            # OWNER's shard clock for the sharded relay.
            order_owners = [owner_of(i) for i in self._upload_order]
            birth_stamps = self.policy.host_stamps(self.server.state,
                                                   order_owners)
            with self._span("upload", round=r):
                for pos, i in enumerate(self._upload_order):
                    if not mask[i]:
                        continue
                    c = self.clients[i]
                    payload = self._upload_fn(c.spec)(c.params, c.data_x,
                                                      c.data_y, upl_ks[i])
                    self._queue.push(birth=r, pos=pos, client_id=i,
                                     stamp=int(birth_stamps[pos]),
                                     payload=payload,
                                     delay=int(delays[i]))
            with self._span("commit", round=r) as sp:
                due = self._queue.pop_due(r)
                self.server.begin_round()
                for birth, pos, cid, stamp, payload, _ in due:
                    # Streaming is sync-only (guarded above), so every due
                    # event was pushed THIS round and the seat -> external
                    # id map is the current view's.
                    self.server.upload(owner_of(cid), payload, stamp=stamp)
                if due:
                    self.server.end_round()
                sp.block(self.server.state)
            commits = [(birth, cid) for birth, pos, cid, *_ in due]

        if mode == "fedavg" and len(present):
            avg = baselines.fedavg_aggregate(
                [self.clients[i].params for i in present])
            for i in present:
                self.clients[i].params = avg

        # download-lag ring: snapshot the post-merge state EVERY round
        # (unchanged on no-commit rounds — the snapshot still represents
        # "the state as of round r"), exactly like the vectorized engine's
        # unconditional history.push inside its round step.
        if self._lagged:
            self._snaps.insert(0, self.server.state)
            del self._snaps[self._h_max:]

        up, down = comm.round_floats(
            mode, n_present=len(present), n_commit=len(commits),
            n_read=len(present) if self._lagged else None,
            C=ccfg.num_classes,
            d=ccfg.d_feature, m_up=ccfg.m_up, m_down=ccfg.m_down,
            model_size=(baselines.num_params(self.clients[0].params)
                        if mode == "fedavg" else 0))
        self.ledger.log_round(up, down)

        with self._span("eval", round=r):
            accs = [self.evaluate(c) for c in self.clients]
        rec = {"round": len(self.history) + 1,
               "acc_mean": float(np.mean(accs)),
               "acc_std": float(np.std(accs)),
               "accs": accs,
               "metrics": metrics_all,
               "participants": present.tolist(),
               "commits": [[b, c] for b, c in commits],
               "comm_up": up, "comm_down": down}
        if self._telem:
            # host-counted event-log quantities: this round's commit lags
            # (commit round − birth round, clipped like the in-jit bins)
            # and the uploads still parked in the queue after pop_due
            chist = np.zeros((obs.STALE_BINS,), np.int32)
            for birth, _cid in commits:
                chist[min(r - birth, obs.STALE_BINS - 1)] += 1
            mask_parts = tuple(jnp.asarray(mask[ids])
                               for ids in self._bucket_ids)
            loss_parts = tuple(
                np.asarray([metrics_all[i]["total"] for i in ids],
                           np.float32) for ids in self._bucket_ids)
            gnorm_parts = tuple(
                np.asarray([metrics_all[i]["grad_norm"] for i in ids],
                           np.float32) for ids in self._bucket_ids)
            telem = self._telem_fn(
                prev_state, self.server.state, jnp.asarray(mask),
                mask_parts, loss_parts, gnorm_parts, jnp.asarray(chist),
                jnp.asarray(len(self._queue), jnp.int32),
                jnp.asarray(dl, jnp.int32))
            rec["telemetry"] = obs.to_record(telem)
        self.history.append(rec)
        if self._sink is not None:
            self._sink.write(rec)
        if self._tracer is not None and self.telemetry.trace:
            self._tracer.write()
        return rec

    def run(self, rounds: int, log_every: int = 0) -> List[Dict]:
        for r in range(rounds):
            rec = self.run_round()
            if log_every and (r + 1) % log_every == 0:
                print(f"  round {rec['round']:3d} acc {rec['acc_mean']:.4f}"
                      f" ±{rec['acc_std']:.4f}")
        return self.history

    # ------------------------------------------------------------------
    def _snapshot(self, d: int):
        """Relay state as of `d` rounds ago (None = live state when no
        download clock is bound). Clamped to the ring depth; entries past
        the pushes performed so far resolve to the init state, which is
        what the vectorized ring's never-written slots hold."""
        if not self._lagged:
            return None
        return self._snaps[min(d, self._h_max - 1, len(self._snaps) - 1)]

    # ------------------------------------------------------------------
    def _eval_fn(self, spec: client_lib.ClientSpec):
        fn = self._eval_cache.get(spec)
        if fn is None:
            fn = jax.jit(lambda p, x: spec.apply(p, x)[1])
            self._eval_cache[spec] = fn
        return fn

    def _upload_fn(self, spec: client_lib.ClientSpec):
        fn = self._upload_cache.get(spec)
        if fn is None:
            fn = client_lib.make_compute_uploads(spec, self.ccfg)
            self._upload_cache[spec] = fn
        return fn

    def evaluate(self, c: ClientState, batch: int = 512) -> float:
        n = self.test_x.shape[0]
        correct = 0
        apply = self._eval_fn(c.spec)
        for i in range(0, n, batch):
            lg = apply(c.params, self.test_x[i:i + batch])
            correct += int(jnp.sum(jnp.argmax(lg, -1)
                                   == self.test_y[i:i + batch]))
        return correct / n
