from repro.core import (baselines, client, collab, comm, losses, prototypes,
                        server, vec_collab)  # noqa: F401
