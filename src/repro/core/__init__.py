from repro.core import (baselines, client, collab, comm, losses, prototypes,
                        server)  # noqa: F401
