"""Core CoRS modules. Import submodules directly, e.g.
`from repro.core import collab, prototypes`.

Deliberately empty of eager submodule imports: the relay subsystem
(repro.relay) depends on `repro.core.prototypes`, while `repro.core.collab`
and `repro.core.vec_collab` depend on `repro.relay` — eagerly importing the
trainers here would make ANY `repro.core.*` import (including prototypes,
from inside relay) a circular one.
"""
