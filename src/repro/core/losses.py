"""The paper's objective (Eq. 6): L = L_CE + λ_KD·L_KD + λ_disc·L_disc.

L_disc implements Eq. (5)/(7): the discriminator ĥ(s,t) =
⟨softmax(τ_u(s)), softmax(τ_u(t))⟩ built from the model's own classifier
(NOT an external discriminator — the paper found that crucial), trained as a
binary classifier of "same class?" with one positive (t^{y_i}) and K
negatives per sample. Theorem 1: I(Φ_s, Φ_t) ≥ log K − L_disc.

Two regimes:
  - `disc_loss`      : paper-faithful K = C−1 (every other class is a negative)
  - `disc_loss_sampled`: K sampled negative classes (LM-scale vocab; the bound
                         holds for any K, only log K changes)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS = 1e-7


def ce_loss(logits, labels, mask=None):
    """Mean cross-entropy. logits (..., C); labels (...) int."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is None:
        return -jnp.mean(ll)
    m = mask.astype(jnp.float32)
    return -jnp.sum(ll * m) / jnp.maximum(jnp.sum(m), 1.0)


def kd_loss(features, global_protos, labels, valid=None, mask=None):
    """L_KD = E‖s_i − t̄^{y_i}‖² (paper ℓ_KD), mean-per-dim reduction.

    Reduction note: the paper writes ‖x'−x''‖² but calibrates λ_KD = 10 with
    a PyTorch pipeline where nn.MSELoss averages over feature dims; with a
    per-dim *sum* the KD gradient is d'× larger, dominates L_CE and collapses
    training at the paper's λ (verified empirically — see EXPERIMENTS.md
    §Paper-claims). We use the mean-per-dim form so the paper's λ values
    transfer."""
    t = jnp.take(global_protos, labels, axis=0)             # (..., d')
    d2 = jnp.mean((features.astype(jnp.float32) - t) ** 2, axis=-1)
    w = jnp.ones_like(d2)
    if valid is not None:
        w = w * jnp.take(valid.astype(jnp.float32), labels, axis=0)
    if mask is not None:
        w = w * mask.astype(jnp.float32)
    return jnp.sum(d2 * w) / jnp.maximum(jnp.sum(w), 1.0)


def _tau(head_w, head_b, x):
    z = x.astype(jnp.float32) @ head_w.astype(jnp.float32)
    if head_b is not None:
        z = z + head_b.astype(jnp.float32)
    return z


def hhat_matrix(student_logits, teacher_logits):
    """ĥ(s, t) for all pairs: (B, C_s) softmax  ·  (M, C_s) softmax -> (B, M)."""
    p = jax.nn.softmax(student_logits.astype(jnp.float32), axis=-1)
    q = jax.nn.softmax(teacher_logits.astype(jnp.float32), axis=-1)
    return p @ q.T


def disc_loss(features, obs, labels, head_w, head_b=None, valid=None,
              student_logits=None, use_kernel: bool = False):
    """Paper-faithful L_disc with K = C−1 (Eq. 7, Algorithm 2).

    features (B, d') student reps; obs (C, d') one downloaded observation per
    class; labels (B,); head_w (d', C), head_b (C,) — the client's own τ_u.
    valid (C,): classes with no observation are excluded from both roles.
    """
    s_logits = (_tau(head_w, head_b, features)
                if student_logits is None else student_logits)
    t_logits = _tau(head_w, head_b, obs)                    # (C, C)
    if use_kernel:
        from repro.kernels import ops
        return ops.disc_loss(s_logits, t_logits, labels, valid)
    h = hhat_matrix(s_logits, t_logits)                     # (B, C)
    h = jnp.clip(h, _EPS, 1.0 - _EPS)
    C = obs.shape[0]
    pos = jax.nn.one_hot(labels, C, dtype=jnp.float32)      # (B, C)
    v = jnp.ones((C,), jnp.float32) if valid is None else valid.astype(jnp.float32)
    # ℓ_disc = −log ĥ(s, t^y) − Σ_{c≠y} log(1 − ĥ(s, t^c))
    per_pair = -(pos * jnp.log(h) + (1.0 - pos) * jnp.log1p(-h))
    per_pair = per_pair * v[None, :]
    sample_valid = jnp.take(v, labels)                      # drop s with no t^y
    return jnp.sum(per_pair * sample_valid[:, None]) / jnp.maximum(
        jnp.sum(sample_valid), 1.0)


def disc_loss_sampled(key, features, protos, labels, head_w, head_b=None,
                      num_negatives: int = 1023, student_logits=None):
    """LM-scale L_disc: K sampled negative classes (shared across the batch).

    protos (C, d') act as the observation bank. Negative classes are drawn
    uniformly; a sampled class equal to y_i is masked out for that sample
    (it would be a false negative).
    """
    C = protos.shape[0]
    s_logits = (_tau(head_w, head_b, features)
                if student_logits is None else student_logits)
    neg_ids = jax.random.randint(key, (num_negatives,), 0, C)     # (K,)
    t_pos = jnp.take(protos, labels, axis=0)                      # (B, d')
    t_neg = jnp.take(protos, neg_ids, axis=0)                     # (K, d')
    z_pos = _tau(head_w, head_b, t_pos)                           # (B, C)
    z_neg = _tau(head_w, head_b, t_neg)                           # (K, C)
    p = jax.nn.softmax(s_logits.astype(jnp.float32), axis=-1)     # (B, C)
    h_pos = jnp.clip(jnp.sum(p * jax.nn.softmax(z_pos, axis=-1), axis=-1),
                     _EPS, 1 - _EPS)                              # (B,)
    h_neg = jnp.clip(p @ jax.nn.softmax(z_neg, axis=-1).T,
                     _EPS, 1 - _EPS)                              # (B, K)
    not_self = (neg_ids[None, :] != labels[:, None]).astype(jnp.float32)
    loss = (-jnp.log(h_pos)
            - jnp.sum(jnp.log1p(-h_neg) * not_self, axis=-1))
    return jnp.mean(loss)


def mi_lower_bound(disc: jax.Array, K: int) -> jax.Array:
    """Theorem 1: I(Φ_s, Φ_t) ≥ log K − L_disc."""
    return jnp.log(jnp.asarray(float(K))) - disc


def fd_loss(logits, mean_logits, labels, valid=None):
    """Federated Distillation baseline (Jeong et al. 18): MSE between the
    student's logits and the network's per-class mean logits of the label."""
    t = jnp.take(mean_logits, labels, axis=0)               # (..., C)
    d2 = jnp.mean((logits.astype(jnp.float32) - t) ** 2, axis=-1)
    if valid is not None:
        w = jnp.take(valid.astype(jnp.float32), labels, axis=0)
        return jnp.sum(d2 * w) / jnp.maximum(jnp.sum(w), 1.0)
    return jnp.mean(d2)
