"""GLOBALUPDATE (paper Algorithm 1) — the relay. MOVED: see `repro.relay`.

The relay grew from a single flat ring into a pluggable subsystem
(`src/repro/relay/`, documented in relay/README.md):

  - `relay.flat`      — this module's former contents: the flat ring with
                        uniform with-replacement sampling (bit-compatible).
  - `relay.per_class` — the paper's exact layout: one ring per class with
                        per-class-slot validity/owner/age.
  - `relay.staleness` — age-tracked slots sampled ∝ exp(-λ·age) via a
                        jittable Gumbel-top-k.
  - `relay.participation` — per-round client participation schedules
                        (full / uniform_k / cyclic / bernoulli_p).
  - `relay.server`    — the stateful `RelayServer` wrapper, now
                        policy-parameterized.

This module remains as a DEPRECATED re-export shim for one release so
existing imports (`from repro.core import server as server_lib`) keep
working; importing it warns. New code imports from `repro.relay`
directly — no internal caller triggers the warning (tier-1 runs with
DeprecationWarnings-as-errors for `repro.*`, see pyproject.toml).
"""
from __future__ import annotations

import warnings

from repro.relay.base import (EMPTY_OWNER, SEED_OWNER,  # noqa: F401
                              default_capacity)
from repro.relay.flat import (FlatRelay, RelayState,  # noqa: F401
                              buffer_append, init_relay_state, merge_round,
                              sample_teacher)
from repro.relay.server import RelayServer  # noqa: F401

warnings.warn(
    "repro: repro.core.server is a deprecated re-export shim; import from "
    "repro.relay (flat / base / server) instead. The shim will be removed "
    "next release.", DeprecationWarning, stacklevel=2)
