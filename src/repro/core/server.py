"""GLOBALUPDATE (paper Algorithm 1) — the relay.

The server's ONLY computation is averaging the clients' per-class averaged
representations into global prototypes; observations live in a fixed-shape
ring buffer and are relayed by uniform sampling. It never touches model
weights (contrast FedAvg), which is what makes the scheme
tunable/decentralizable — `sample_teacher` below is trivially replaceable by
a peer-to-peer exchange, and the on-mesh distributed path (launch/train.py)
replaces it with a single all-reduce.

State layout: everything is a `RelayState` pytree of fixed-shape arrays
(observations `(cap, C, d')` + per-slot validity/owner arrays + a write
pointer), so upload, relay sampling and the round merge are pure jax
functions — jit/vmap/shard_map-compatible and O(1) Python per call. The
`RelayServer` class is a thin stateful wrapper over those functions used by
the sequential `CollabTrainer`; the vectorized engine
(core/vec_collab.py) calls the pure functions directly inside its jitted
round step, so both paths evolve byte-identical relay state.
"""
from __future__ import annotations

from typing import Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import prototypes
from repro.types import CollabConfig

# Ring-slot owner sentinels. Real clients are >= 0.
SEED_OWNER = -1      # server-seeded random observation (paper Alg. 1 init)
EMPTY_OWNER = -2     # slot never written


class RelayState(NamedTuple):
    """Everything the relay holds, as fixed-shape arrays (a jax pytree).

    obs   (cap, C, d') f32 : observation ring buffer
    valid (cap, C)    bool : per-slot per-class validity
    owner (cap,)      int32: uploading client id (or SEED/EMPTY sentinel)
    ptr   ()          int32: next ring write position
    global_protos (C, d') f32, valid_g (C,) bool: the t̄^c prototypes
    mean_logits (C, C) f32 : FD-mode per-class mean logits (zeros otherwise)
    """
    obs: jax.Array
    valid: jax.Array
    owner: jax.Array
    ptr: jax.Array
    global_protos: jax.Array
    valid_g: jax.Array
    mean_logits: jax.Array

    @property
    def capacity(self) -> int:
        return self.obs.shape[0]


def default_capacity(ccfg: CollabConfig, n_clients: int = 2) -> int:
    """Mirror the old list-server bound: 32 · N · M_↑ live observations."""
    return 32 * max(1, n_clients) * max(1, ccfg.m_up)


def init_relay_state(ccfg: CollabConfig, d_feature: int, seed: int = 0,
                     capacity: Optional[int] = None,
                     n_clients: int = 2) -> RelayState:
    """Paper Algorithm 1: S initializes randomly {t̄^c} and the observation
    buffers. The random initial prototypes are load-bearing: they are a
    COMMON anchor that aligns the clients' (independently initialized)
    feature spaces in round 1, so that inter-client averaging of per-class
    means is meaningful from round 2 on. Without it, averaging across
    unaligned feature spaces cancels class structure and L_KD collapses the
    model (verified empirically; see tests)."""
    C = ccfg.num_classes
    cap = default_capacity(ccfg, n_clients) if capacity is None else capacity
    assert cap > 0, "relay buffer capacity must be positive"
    n_seed = min(cap, max(1, ccfg.m_down))
    rng = np.random.default_rng(seed)
    protos = rng.normal(size=(C, d_feature)).astype(np.float32) * 0.01
    obs = np.zeros((cap, C, d_feature), np.float32)
    obs[:n_seed] = rng.normal(size=(n_seed, C, d_feature)).astype(np.float32) * 0.01
    valid = np.zeros((cap, C), bool)
    valid[:n_seed] = True
    owner = np.full((cap,), EMPTY_OWNER, np.int32)
    owner[:n_seed] = SEED_OWNER
    return RelayState(obs=jnp.asarray(obs), valid=jnp.asarray(valid),
                      owner=jnp.asarray(owner),
                      ptr=jnp.asarray(n_seed % cap, jnp.int32),
                      global_protos=jnp.asarray(protos),
                      valid_g=jnp.ones((C,), bool),
                      mean_logits=jnp.zeros((C, C), jnp.float32))


# -- uplink (pure) ---------------------------------------------------------
def buffer_append(state: RelayState, obs_rows, valid_rows,
                  owner_rows) -> RelayState:
    """Write k observation rows into the ring (oldest-first overwrite).

    obs_rows (k, C, d'), valid_rows (k, C), owner_rows (k,) int32.
    k must not exceed capacity (scatter order for duplicate ring indices is
    undefined); callers size the buffer with `default_capacity`.
    """
    k = obs_rows.shape[0]
    cap = state.obs.shape[0]
    idx = (state.ptr + jnp.arange(k, dtype=jnp.int32)) % cap
    return state._replace(
        obs=state.obs.at[idx].set(obs_rows.astype(jnp.float32)),
        valid=state.valid.at[idx].set(valid_rows),
        owner=state.owner.at[idx].set(owner_rows.astype(jnp.int32)),
        ptr=(state.ptr + k) % cap)


def merge_round(state: RelayState, proto: prototypes.ProtoState,
                logit: Optional[prototypes.ProtoState] = None) -> RelayState:
    """Inter-client aggregation (the server's only computation, Alg. 1):
    per-round recompute of t̄^c from the merged per-class sums."""
    state = state._replace(global_protos=prototypes.means(proto),
                           valid_g=proto.count > 0)
    if logit is not None:
        state = state._replace(mean_logits=prototypes.means(logit))
    return state


# -- downlink (pure) -------------------------------------------------------
def sample_teacher(state: RelayState, client_id, m_down: int, key) -> Dict:
    """Observations of OTHER users, chosen at random (paper §4: 'downloads
    the representations of another user chosen at random').

    Pure and jit/vmap-compatible: uniform with-replacement sampling over the
    ring slots not owned by `client_id`; falls back to the whole filled
    buffer when every slot is the client's own, and to a zero/invalid
    teacher when the buffer is entirely empty. Always returns the full
    teacher dict (all keys, fixed shapes)."""
    usable = state.owner != EMPTY_OWNER
    others = usable & (state.owner != jnp.asarray(client_id, jnp.int32))
    pool = jnp.where(jnp.any(others), others, usable)
    any_pool = jnp.any(pool)
    logits = jnp.where(pool, 0.0, -jnp.inf)
    k_sample, k_pick = jax.random.split(jnp.asarray(key))
    idx = jax.random.categorical(k_sample, logits, shape=(m_down,))
    idx = jnp.where(any_pool, idx, 0)
    obs = jnp.where(any_pool, state.obs[idx], 0.0)            # (M, C, d')
    valid_o = jnp.where(any_pool, jnp.all(state.valid[idx], axis=0), False)
    return {"global_protos": state.global_protos,
            "valid_g": state.valid_g,
            "obs": obs, "valid_o": valid_o,
            "obs_pick": jax.random.randint(k_pick, (), 0, m_down,
                                           dtype=jnp.int32),
            "mean_logits": state.mean_logits}


_sample_teacher_jit = jax.jit(sample_teacher, static_argnums=(2,))


# -- stateful wrapper (sequential CollabTrainer path) ----------------------
class RelayServer:
    def __init__(self, ccfg: CollabConfig, d_feature: int, seed: int = 0,
                 capacity: Optional[int] = None, n_clients: int = 2):
        self.ccfg = ccfg
        self.d = d_feature
        self.state = init_relay_state(ccfg, d_feature, seed, capacity,
                                      n_clients)
        self.round_states: List[prototypes.ProtoState] = []
        self.round_logit_states: List[prototypes.ProtoState] = []

    # -- uplink ------------------------------------------------------------
    def begin_round(self):
        self.round_states = []
        self.round_logit_states = []

    def upload(self, client_id: int, payload: Dict):
        self.round_states.append(payload["proto"])
        if "logit_proto" in payload:
            self.round_logit_states.append(payload["logit_proto"])
        obs = payload["obs"]                                  # (M_up, C, d')
        m = obs.shape[0]
        self.state = buffer_append(
            self.state, obs,
            jnp.broadcast_to(payload["valid"], (m,) + payload["valid"].shape),
            jnp.full((m,), client_id, jnp.int32))

    def end_round(self):
        if self.round_states:
            merged = prototypes.merge(*self.round_states)
            logit = (prototypes.merge(*self.round_logit_states)
                     if self.round_logit_states else None)
            self.state = merge_round(self.state, merged, logit)

    # -- downlink ----------------------------------------------------------
    def relay(self, client_id: int, m_down: int, key) -> Dict:
        return _sample_teacher_jit(self.state,
                                   jnp.asarray(client_id, jnp.int32),
                                   m_down, key)

    # -- introspection (tests / notebooks) ---------------------------------
    @property
    def global_protos(self) -> jax.Array:
        return self.state.global_protos

    @property
    def valid_g(self) -> jax.Array:
        return self.state.valid_g

    @property
    def mean_logits(self) -> jax.Array:
        return self.state.mean_logits

    @property
    def obs_buffer(self) -> List[Dict]:
        """Filled ring slots as a list of entry dicts (compat view; every
        entry carries an "owner" key, including seeded/fallback entries)."""
        owner = np.asarray(self.state.owner)
        return [{"obs": self.state.obs[i], "valid": self.state.valid[i],
                 "owner": int(owner[i])}
                for i in np.where(owner != EMPTY_OWNER)[0]]
