"""GLOBALUPDATE (paper Algorithm 1) — the relay.

The server's ONLY computation is averaging the clients' per-class averaged
representations into global prototypes; observations are stored in per-class
buffers, shuffled, and relayed. It never touches model weights (contrast
FedAvg), which is what makes the scheme tunable/decentralizable — `relay()`
below is trivially replaceable by a peer-to-peer exchange, and the on-mesh
distributed path (launch/train.py) replaces it with a single all-reduce.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import prototypes
from repro.types import CollabConfig


class RelayServer:
    def __init__(self, ccfg: CollabConfig, d_feature: int, seed: int = 0):
        self.ccfg = ccfg
        self.d = d_feature
        self.rng = np.random.default_rng(seed)
        C = ccfg.num_classes
        # Paper Algorithm 1: S initializes randomly {t̄^c} and the observation
        # buffers. The random initial prototypes are load-bearing: they are a
        # COMMON anchor that aligns the clients' (independently initialized)
        # feature spaces in round 1, so that inter-client averaging of
        # per-class means is meaningful from round 2 on. Without it, averaging
        # across unaligned feature spaces cancels class structure and L_KD
        # collapses the model (verified empirically; see tests).
        self.global_state = prototypes.init_state(C, d_feature)
        self.global_protos = jnp.asarray(
            self.rng.normal(size=(C, d_feature)).astype(np.float32) * 0.01)
        self.valid_g = jnp.ones((C,), bool)
        self.obs_buffer: List[Dict] = [
            {"obs": jnp.asarray(self.rng.normal(size=(C, d_feature))
                                .astype(np.float32) * 0.01),
             "valid": jnp.ones((C,), bool), "owner": -1}
            for _ in range(max(1, ccfg.m_down))]
        self.logit_state = None            # FD mode

    # -- uplink ------------------------------------------------------------
    def upload(self, client_id: int, payload: Dict):
        self.round_states.append(payload["proto"])
        for m in range(payload["obs"].shape[0]):
            self.obs_buffer.append({"obs": payload["obs"][m],
                                    "valid": payload["valid"],
                                    "owner": client_id})
        if "logit_proto" in payload:
            self.round_logit_states.append(payload["logit_proto"])

    def begin_round(self):
        self.round_states = []
        self.round_logit_states = []

    def end_round(self):
        if self.round_states:
            merged = prototypes.merge(*self.round_states)
            self.global_protos = prototypes.means(merged)
            self.valid_g = merged.count > 0
        if self.round_logit_states:
            lm = prototypes.merge(*self.round_logit_states)
            self.mean_logits = prototypes.means(lm)
        # keep the buffer bounded (paper: class buffers, shuffled)
        self.rng.shuffle(self.obs_buffer)
        cap = 4 * max(1, len(self.round_states)) * self.ccfg.m_up
        self.obs_buffer = self.obs_buffer[-cap * 8:]

    # -- downlink ----------------------------------------------------------
    def relay(self, client_id: int, m_down: int, key) -> Dict:
        """Observations of OTHER users, chosen at random (paper §4:
        'downloads the representations of another user chosen at random')."""
        pool = [o for o in self.obs_buffer if o["owner"] != client_id]
        if not pool:
            pool = self.obs_buffer or [{
                "obs": jnp.zeros((self.ccfg.num_classes, self.d), jnp.float32),
                "valid": jnp.zeros((self.ccfg.num_classes,), bool)}]
        picks = [pool[self.rng.integers(len(pool))] for _ in range(m_down)]
        obs = jnp.stack([p["obs"] for p in picks])           # (M, C, d')
        valid = jnp.stack([p["valid"] for p in picks]).all(axis=0)
        teacher = {"global_protos": self.global_protos,
                   "valid_g": self.valid_g,
                   "obs": obs, "valid_o": valid,
                   "obs_pick": jnp.asarray(
                       self.rng.integers(m_down), jnp.int32)}
        if self.logit_state is not None or hasattr(self, "mean_logits"):
            teacher["mean_logits"] = getattr(
                self, "mean_logits",
                jnp.zeros((self.ccfg.num_classes, self.ccfg.num_classes)))
        return teacher
