"""Vectorized multi-client engine: all clients advance in ONE jitted step.

The paper's headline claim is that CoRS "is scalable with the number of
clients"; the sequential `CollabTrainer` oracle steps clients in a Python
loop (cost linear in N, one dispatch per client per phase). This engine
stacks homogeneous clients' params / Adam moments / data along a leading
client axis and runs the whole round — relay sampling, local updates,
uploads, server merge — as a single `jax.vmap`'d jitted function over that
axis, against the same fixed-shape relay state the sequential path uses.
Given the same seeds and equal-size partitions the two engines evolve
identical relay state and near-identical weights (see
tests/test_vec_collab.py and tests/test_relay_policies.py), but the
vectorized round is one XLA program instead of O(N) Python dispatches.

Relay policy: the server side is pluggable (`repro.relay`): `flat` (the
seed ring, bit-compatible), `per_class` (the paper's exact per-class buffer
layout) or `staleness` (exp(-λ·age) Gumbel-top-k sampling). The policy's
pure functions are closed over by the jitted round step, so swapping
policies swaps ONE compiled program, not the engine.

Participation: a `ParticipationSchedule` (repro.relay.participation) emits
a per-round boolean client mask. Schedules with a static participant count
k (uniform_k, cyclic) run COMPACTED: the step gathers the k participants
into a (k, ...) block, so a k=N/4 round costs ~1/4 of a full round —
real savings, not just masking. Variable-count schedules (bernoulli_p) and
the mesh path run full-width and mask: absent clients' params/opt are
frozen via `where`, their uploads zero-weighted, and the ring append drops
their rows without consuming slots. Either way there is exactly one jitted
round step per (policy, schedule) — the mask and gather indices are traced
arguments of fixed shape, so participation never retraces.

Device scaling: pass `mesh` (a 1-D mesh with a "clients" axis, see
`sharding.client_mesh`) and the round step is wrapped in `shard_map` — each
device vmaps its local client shard and the only cross-device collectives
are the prototype merge (`prototypes.psum_merge`, the paper's O(C·d')
exchange) and the observation all-gather into the replicated ring buffer.

Heterogeneous-architecture runs (different client models, a CoRS selling
point) stay on the sequential oracle: stacking requires one ClientSpec.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import relay as relay_lib, sharding
from repro.core import baselines, client as client_lib, collab, comm, \
    prototypes
from repro.optim import adam_init
from repro.relay.participation import bcast_mask as _bcast, freeze_absent
from repro.types import CollabConfig, TrainConfig


def _stack(trees: Sequence[Any]):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


class VectorizedCollabTrainer:
    """Drop-in counterpart of `CollabTrainer` for homogeneous clients.

    Same constructor shape, `run_round` record schema, `ledger` accounting
    and `history`; `specs` may be a single ClientSpec or a sequence of the
    SAME spec. Client datasets are trimmed to the shortest partition so they
    stack; pass equal-size partitions for exact parity with the oracle.
    """

    def __init__(self,
                 specs: Union[client_lib.ClientSpec,
                              Sequence[client_lib.ClientSpec]],
                 params_list: Sequence[Any],
                 client_data: Sequence[Tuple[jax.Array, jax.Array]],
                 test_data: Tuple[jax.Array, jax.Array],
                 ccfg: CollabConfig, tcfg: TrainConfig, seed: int = 0,
                 mesh=None, policy=None, schedule=None):
        if isinstance(specs, client_lib.ClientSpec):
            specs = [specs] * len(params_list)
        assert all(s is specs[0] for s in specs), (
            "VectorizedCollabTrainer needs homogeneous clients (one shared "
            "ClientSpec); use the sequential CollabTrainer oracle for "
            "heterogeneous architectures")
        assert len(specs) == len(params_list) == len(client_data)
        self.spec = specs[0]
        self.ccfg, self.tcfg = ccfg, tcfg
        self.n_clients = N = len(params_list)
        self.mesh = mesh
        self.policy = relay_lib.get_policy(policy)
        self.schedule = relay_lib.get_schedule(schedule, seed=seed)
        if mesh is not None:
            assert N % mesh.shape["clients"] == 0, (N, dict(mesh.shape))

        n_common = min(x.shape[0] for x, _ in client_data)
        self.data_x = jnp.stack([jnp.asarray(x[:n_common])
                                 for x, _ in client_data])
        self.data_y = jnp.stack([jnp.asarray(y[:n_common])
                                 for _, y in client_data])
        bs = tcfg.batch_size
        nb = n_common // bs
        self.batches = {
            "x": self.data_x[:, :nb * bs].reshape(
                N, nb, bs, *self.data_x.shape[2:]),
            "y": self.data_y[:, :nb * bs].reshape(N, nb, bs)}

        self.params = _stack(params_list)
        self.opt_state = _stack([adam_init(p) for p in params_list])
        self.relay_state = self.policy.init_state(
            ccfg, ccfg.d_feature, seed, n_clients=N)
        self.test_x, self.test_y = (jnp.asarray(test_data[0]),
                                    jnp.asarray(test_data[1]))
        self.ledger = comm.CommLedger()
        self.key = jax.random.PRNGKey(seed)
        self.history: List[Dict] = []

        # Compaction: only off-mesh (gathering an arbitrary client subset
        # across a sharded axis would defeat shard_map's static layout) and
        # only when the schedule's per-round count is static.
        fixed_k = self.schedule.fixed_k
        self._k_active = (fixed_k if (mesh is None and fixed_k is not None)
                          else N)
        self._round_step = self._make_round_step()
        spec = self.spec
        self._eval_batched = jax.jit(
            lambda P, x: jax.vmap(lambda p: spec.apply(p, x)[1])(P))

    # ------------------------------------------------------------------
    def client_params(self, i: int):
        """Unstacked view of client i's params (checkpointing / inspection)."""
        return jax.tree.map(lambda p: p[i], self.params)

    # ------------------------------------------------------------------
    def _make_round_step(self):
        spec, ccfg, tcfg = self.spec, self.ccfg, self.tcfg
        N, mesh, policy = self.n_clients, self.mesh, self.policy
        mode = ccfg.mode
        m_down = max(1, ccfg.m_down)
        local_update = client_lib.make_local_update_fn(spec, ccfg, tcfg)
        # Gather/scatter the participant block ONLY when it is a strict
        # subset: with k == N the idx is a runtime arange XLA cannot elide,
        # and the full-size gather + scatter-back of params/opt/batches
        # would tax every full-participation round for nothing.
        compact = mesh is None and self._k_active < N

        def round_core(params, opt, rstate, batches, data_x, data_y, ids,
                       relay_ks, upd_ks, upl_ks, mask, idx):
            # phase 0 — participant gather. Off-mesh the round runs on the
            # idx-selected (k, ...) block (identity permutation under full
            # participation); on-mesh each device keeps its full local
            # shard and `sub_mask` does the masking.
            if compact:
                take = lambda t: jax.tree.map(lambda a: a[idx], t)
                p_s, o_s, b_s = take(params), take(opt), take(batches)
                dx, dy, ids_s = data_x[idx], data_y[idx], ids[idx]
                rk, uk, ok = relay_ks[idx], upd_ks[idx], upl_ks[idx]
                sub_mask = mask[idx]
            else:
                p_s, o_s, b_s = params, opt, batches
                dx, dy, ids_s = data_x, data_y, ids
                rk, uk, ok = relay_ks, upd_ks, upl_ks
                sub_mask = mask
            k_loc = ids_s.shape[0]
            wf = sub_mask.astype(jnp.float32)
            n_present = jnp.sum(wf)
            if mesh is not None:
                n_present = jax.lax.psum(n_present, "clients")
            any_present = n_present > 0

            keep = lambda new, old: freeze_absent(sub_mask, new, old)

            # phase 1 — downlink (vmapped relay sampling from the buffers)
            if mode in ("cors", "fd"):
                teacher = jax.vmap(
                    lambda i, k: policy.sample_teacher(
                        rstate, i, m_down, k))(ids_s, rk)
            else:
                et = client_lib.empty_teacher(ccfg)
                teacher = jax.tree.map(
                    lambda a: jnp.broadcast_to(a, (k_loc,) + a.shape), et)

            # phase 2 — all local updates in one vmap (Algorithm 2 × k)
            new_p, new_o, metrics = jax.vmap(local_update)(
                p_s, o_s, b_s, teacher, uk)
            p_s, o_s = keep(new_p, p_s), keep(new_o, o_s)
            metrics = jax.tree.map(
                lambda m: jnp.where(_bcast(sub_mask, m), m, 0.0), metrics)

            # phase 3 — uplink + merge (Algorithm 1): absent clients'
            # prototype sums are zero-weighted and their observation rows
            # dropped from the ring WITHOUT consuming slots; a round with
            # zero participants leaves the relay state untouched.
            if mode in ("cors", "fd"):
                uploads = jax.vmap(
                    lambda p, x, y, k: client_lib.compute_uploads(
                        spec, p, x, y, ccfg, k))(p_s, dx, dy, ok)
                proto = prototypes.ProtoState(
                    jnp.sum(uploads["proto"].sum * wf[:, None, None], axis=0),
                    jnp.sum(uploads["proto"].count * wf[:, None], axis=0))
                logit = None
                if mode == "fd":
                    logit = prototypes.ProtoState(
                        jnp.sum(uploads["logit_proto"].sum
                                * wf[:, None, None], axis=0),
                        jnp.sum(uploads["logit_proto"].count
                                * wf[:, None], axis=0))
                m_real = uploads["obs"].shape[1]     # 0 when m_up == 0
                obs_rows = uploads["obs"].reshape(-1, *uploads["obs"].shape[2:])
                valid_rows = jnp.repeat(uploads["valid"], m_real, axis=0)
                owner_rows = jnp.repeat(ids_s, m_real)
                row_mask = jnp.repeat(sub_mask, m_real)
                if mesh is not None:
                    # merge is the paper's only collective: an all-reduce of
                    # (C, d'+1) floats over the client axis
                    proto = prototypes.psum_merge(proto, "clients")
                    if logit is not None:
                        logit = prototypes.psum_merge(logit, "clients")
                    obs_rows, valid_rows, owner_rows, row_mask = (
                        jax.lax.all_gather(a, "clients", axis=0, tiled=True)
                        for a in (obs_rows, valid_rows, owner_rows, row_mask))
                new_rstate = policy.append(rstate, obs_rows, valid_rows,
                                           owner_rows, row_mask)
                new_rstate = policy.merge_round(new_rstate, proto, logit)
                rstate = jax.tree.map(
                    lambda n, o: jnp.where(any_present, n, o),
                    new_rstate, rstate)

            if mode == "fedavg":
                denom = jnp.maximum(n_present, 1.0)

                def avg(p):
                    s = jnp.sum(p.astype(jnp.float32) * _bcast(wf, p), axis=0)
                    if mesh is not None:
                        s = jax.lax.psum(s, "clients")
                    a = (s / denom).astype(p.dtype)
                    return jnp.where(_bcast(sub_mask, p),
                                     jnp.broadcast_to(a, p.shape), p)
                p_s = jax.tree.map(avg, p_s)

            # phase 4 — scatter the compacted block back into the stack
            if compact:
                put = lambda full, s: jax.tree.map(
                    lambda f, v: f.at[idx].set(v), full, s)
                params, opt = put(params, p_s), put(opt, o_s)
                metrics_full = jax.tree.map(
                    lambda m: jnp.zeros((N,) + m.shape[1:],
                                        m.dtype).at[idx].set(m), metrics)
            else:
                params, opt, metrics_full = p_s, o_s, metrics
            return params, opt, rstate, metrics_full

        if mesh is None:
            return jax.jit(round_core)

        from jax.sharding import PartitionSpec as P
        cl, rep = P("clients"), P()
        mapped = sharding.shard_map(
            round_core, mesh=mesh,
            in_specs=(cl, cl, rep, cl, cl, cl, cl, cl, cl, cl, cl, cl),
            out_specs=(cl, cl, rep, cl), check_rep=False)
        return jax.jit(mapped)

    # ------------------------------------------------------------------
    def run_round(self) -> Dict:
        ccfg, N = self.ccfg, self.n_clients
        mode = ccfg.mode
        # Same key schedule as the sequential oracle: keys for ALL N
        # clients regardless of participation (absent clients just never
        # consume theirs), so seq and vec stay equivalence-testable under
        # every schedule.
        self.key, relay_ks, upd_ks, upl_ks = collab.round_keys(self.key, N)
        ids = jnp.arange(N, dtype=jnp.int32)
        mask_np = np.asarray(self.schedule.mask(len(self.history), N), bool)
        present = np.nonzero(mask_np)[0]
        if self.mesh is None and self._k_active < N:
            idx_np = present                     # static-k compaction
            assert idx_np.size == self._k_active, (
                "schedule emitted a mask inconsistent with its fixed_k",
                idx_np.size, self._k_active)
        else:
            idx_np = np.arange(N)
        mask = jnp.asarray(mask_np)
        idx = jnp.asarray(idx_np, jnp.int32)
        self.params, self.opt_state, self.relay_state, metrics = \
            self._round_step(self.params, self.opt_state, self.relay_state,
                             self.batches, self.data_x, self.data_y, ids,
                             relay_ks, upd_ks, upl_ks, mask, idx)

        up, down = comm.round_floats(
            mode, n_present=int(present.size), C=ccfg.num_classes,
            d=ccfg.d_feature, m_up=ccfg.m_up, m_down=ccfg.m_down,
            model_size=(baselines.num_params(self.client_params(0))
                        if mode == "fedavg" else 0))
        self.ledger.log_round(up, down)

        accs = self.evaluate_all()
        metrics_np = jax.tree.map(np.asarray, metrics)
        metrics_all = [jax.tree.map(lambda v: float(v[i]), metrics_np)
                       for i in range(N)]
        rec = {"round": len(self.history) + 1,
               "acc_mean": float(np.mean(accs)),
               "acc_std": float(np.std(accs)),
               "accs": accs,
               "metrics": metrics_all,
               "participants": present.tolist(),
               "comm_up": up, "comm_down": down}
        self.history.append(rec)
        return rec

    def run(self, rounds: int, log_every: int = 0) -> List[Dict]:
        for r in range(rounds):
            rec = self.run_round()
            if log_every and (r + 1) % log_every == 0:
                print(f"  round {rec['round']:3d} acc {rec['acc_mean']:.4f}"
                      f" ±{rec['acc_std']:.4f}")
        return self.history

    # ------------------------------------------------------------------
    def evaluate_all(self, batch: int = 512) -> List[float]:
        """Per-client test accuracy, all clients per test chunk in one call."""
        n = self.test_x.shape[0]
        correct = np.zeros((self.n_clients,), np.int64)
        for i in range(0, n, batch):
            lg = self._eval_batched(self.params, self.test_x[i:i + batch])
            hits = jnp.sum(jnp.argmax(lg, -1)
                           == self.test_y[None, i:i + batch], axis=-1)
            correct += np.asarray(hits)
        return (correct / n).tolist()
