"""Vectorized multi-client engine: all clients advance in ONE jitted step.

The paper's headline claim is that CoRS "is scalable with the number of
clients"; the sequential `CollabTrainer` oracle steps clients in a Python
loop (cost linear in N, one dispatch per client per phase). This engine
stacks homogeneous clients' params / Adam moments / data along a leading
client axis and runs the whole round — relay sampling, local updates,
uploads, server merge — as a single `jax.vmap`'d jitted function over that
axis, against the same fixed-shape `server.RelayState` ring buffer the
sequential path uses. Given the same seeds and equal-size partitions the two
engines evolve identical relay state and near-identical weights (see
tests/test_vec_collab.py), but the vectorized round is one XLA program
instead of O(N) Python dispatches.

Device scaling: pass `mesh` (a 1-D mesh with a "clients" axis, see
`sharding.client_mesh`) and the round step is wrapped in `shard_map` — each
device vmaps its local client shard and the only cross-device collectives
are the prototype merge (`prototypes.psum_merge`, the paper's O(C·d')
exchange) and the observation all-gather into the replicated ring buffer.

Heterogeneous-architecture runs (different client models, a CoRS selling
point) stay on the sequential oracle: stacking requires one ClientSpec.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding
from repro.core import baselines, client as client_lib, collab, comm, \
    prototypes, server as server_lib
from repro.optim import adam_init
from repro.types import CollabConfig, TrainConfig


def _stack(trees: Sequence[Any]):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


class VectorizedCollabTrainer:
    """Drop-in counterpart of `CollabTrainer` for homogeneous clients.

    Same constructor shape, `run_round` record schema, `ledger` accounting
    and `history`; `specs` may be a single ClientSpec or a sequence of the
    SAME spec. Client datasets are trimmed to the shortest partition so they
    stack; pass equal-size partitions for exact parity with the oracle.
    """

    def __init__(self,
                 specs: Union[client_lib.ClientSpec,
                              Sequence[client_lib.ClientSpec]],
                 params_list: Sequence[Any],
                 client_data: Sequence[Tuple[jax.Array, jax.Array]],
                 test_data: Tuple[jax.Array, jax.Array],
                 ccfg: CollabConfig, tcfg: TrainConfig, seed: int = 0,
                 mesh=None):
        if isinstance(specs, client_lib.ClientSpec):
            specs = [specs] * len(params_list)
        assert all(s is specs[0] for s in specs), (
            "VectorizedCollabTrainer needs homogeneous clients (one shared "
            "ClientSpec); use the sequential CollabTrainer oracle for "
            "heterogeneous architectures")
        assert len(specs) == len(params_list) == len(client_data)
        self.spec = specs[0]
        self.ccfg, self.tcfg = ccfg, tcfg
        self.n_clients = N = len(params_list)
        self.mesh = mesh
        if mesh is not None:
            assert N % mesh.shape["clients"] == 0, (N, dict(mesh.shape))

        n_common = min(x.shape[0] for x, _ in client_data)
        self.data_x = jnp.stack([jnp.asarray(x[:n_common])
                                 for x, _ in client_data])
        self.data_y = jnp.stack([jnp.asarray(y[:n_common])
                                 for _, y in client_data])
        bs = tcfg.batch_size
        nb = n_common // bs
        self.batches = {
            "x": self.data_x[:, :nb * bs].reshape(
                N, nb, bs, *self.data_x.shape[2:]),
            "y": self.data_y[:, :nb * bs].reshape(N, nb, bs)}

        self.params = _stack(params_list)
        self.opt_state = _stack([adam_init(p) for p in params_list])
        self.relay_state = server_lib.init_relay_state(
            ccfg, ccfg.d_feature, seed, n_clients=N)
        self.test_x, self.test_y = (jnp.asarray(test_data[0]),
                                    jnp.asarray(test_data[1]))
        self.ledger = comm.CommLedger()
        self.key = jax.random.PRNGKey(seed)
        self.history: List[Dict] = []

        self._round_step = self._make_round_step()
        spec = self.spec
        self._eval_batched = jax.jit(
            lambda P, x: jax.vmap(lambda p: spec.apply(p, x)[1])(P))

    # ------------------------------------------------------------------
    def client_params(self, i: int):
        """Unstacked view of client i's params (checkpointing / inspection)."""
        return jax.tree.map(lambda p: p[i], self.params)

    # ------------------------------------------------------------------
    def _make_round_step(self):
        spec, ccfg, tcfg = self.spec, self.ccfg, self.tcfg
        N, mesh = self.n_clients, self.mesh
        mode = ccfg.mode
        m_down = max(1, ccfg.m_down)
        local_update = client_lib.make_local_update_fn(spec, ccfg, tcfg)

        def round_core(params, opt, rstate, batches, data_x, data_y, ids,
                       relay_ks, upd_ks, upl_ks):
            # phase 1 — downlink (vmapped relay sampling from the ring)
            if mode in ("cors", "fd"):
                teacher = jax.vmap(
                    lambda i, k: server_lib.sample_teacher(
                        rstate, i, m_down, k))(ids, relay_ks)
            else:
                et = client_lib.empty_teacher(ccfg)
                nloc = ids.shape[0]
                teacher = jax.tree.map(
                    lambda a: jnp.broadcast_to(a, (nloc,) + a.shape), et)

            # phase 2 — all local updates in one vmap (Algorithm 2 × N)
            params, opt, metrics = jax.vmap(local_update)(
                params, opt, batches, teacher, upd_ks)

            # phase 3 — uplink + merge (Algorithm 1)
            if mode in ("cors", "fd"):
                uploads = jax.vmap(
                    lambda p, x, y, k: client_lib.compute_uploads(
                        spec, p, x, y, ccfg, k))(params, data_x, data_y,
                                                 upl_ks)
                proto = prototypes.ProtoState(
                    jnp.sum(uploads["proto"].sum, axis=0),
                    jnp.sum(uploads["proto"].count, axis=0))
                logit = None
                if mode == "fd":
                    logit = prototypes.ProtoState(
                        jnp.sum(uploads["logit_proto"].sum, axis=0),
                        jnp.sum(uploads["logit_proto"].count, axis=0))
                m_real = uploads["obs"].shape[1]     # 0 when m_up == 0
                obs_rows = uploads["obs"].reshape(-1, *uploads["obs"].shape[2:])
                valid_rows = jnp.repeat(uploads["valid"], m_real, axis=0)
                owner_rows = jnp.repeat(ids, m_real)
                if mesh is not None:
                    # merge is the paper's only collective: an all-reduce of
                    # (C, d'+1) floats over the client axis
                    proto = prototypes.psum_merge(proto, "clients")
                    if logit is not None:
                        logit = prototypes.psum_merge(logit, "clients")
                    obs_rows = jax.lax.all_gather(
                        obs_rows, "clients", axis=0, tiled=True)
                    valid_rows = jax.lax.all_gather(
                        valid_rows, "clients", axis=0, tiled=True)
                    owner_rows = jax.lax.all_gather(
                        owner_rows, "clients", axis=0, tiled=True)
                rstate = server_lib.merge_round(rstate, proto, logit)
                rstate = server_lib.buffer_append(rstate, obs_rows,
                                                  valid_rows, owner_rows)

            if mode == "fedavg":
                def avg(p):
                    s = jnp.sum(p.astype(jnp.float32), axis=0)
                    if mesh is not None:
                        s = jax.lax.psum(s, "clients")
                    return jnp.broadcast_to((s / N).astype(p.dtype), p.shape)
                params = jax.tree.map(avg, params)
            return params, opt, rstate, metrics

        if mesh is None:
            return jax.jit(round_core)

        from jax.sharding import PartitionSpec as P
        cl, rep = P("clients"), P()
        mapped = sharding.shard_map(
            round_core, mesh=mesh,
            in_specs=(cl, cl, rep, cl, cl, cl, cl, cl, cl, cl),
            out_specs=(cl, cl, rep, cl), check_rep=False)
        return jax.jit(mapped)

    # ------------------------------------------------------------------
    def run_round(self) -> Dict:
        ccfg, N = self.ccfg, self.n_clients
        mode = ccfg.mode
        self.key, relay_ks, upd_ks, upl_ks = collab.round_keys(self.key, N)
        ids = jnp.arange(N, dtype=jnp.int32)
        self.params, self.opt_state, self.relay_state, metrics = \
            self._round_step(self.params, self.opt_state, self.relay_state,
                             self.batches, self.data_x, self.data_y, ids,
                             relay_ks, upd_ks, upl_ks)

        if mode == "fedavg":
            up, down = comm.fedavg_round_floats(
                baselines.num_params(self.client_params(0)), N)
        elif mode == "cors":
            up, down = comm.cors_round_floats(
                ccfg.num_classes, ccfg.d_feature, ccfg.m_up, ccfg.m_down, N)
        elif mode == "fd":
            up, down = comm.fd_round_floats(ccfg.num_classes, N)
        else:
            up = down = 0.0
        self.ledger.log_round(up, down)

        accs = self.evaluate_all()
        metrics_np = jax.tree.map(np.asarray, metrics)
        metrics_all = [jax.tree.map(lambda v: float(v[i]), metrics_np)
                       for i in range(N)]
        rec = {"round": len(self.history) + 1,
               "acc_mean": float(np.mean(accs)),
               "acc_std": float(np.std(accs)),
               "accs": accs,
               "metrics": metrics_all,
               "comm_up": up, "comm_down": down}
        self.history.append(rec)
        return rec

    def run(self, rounds: int, log_every: int = 0) -> List[Dict]:
        for r in range(rounds):
            rec = self.run_round()
            if log_every and (r + 1) % log_every == 0:
                print(f"  round {rec['round']:3d} acc {rec['acc_mean']:.4f}"
                      f" ±{rec['acc_std']:.4f}")
        return self.history

    # ------------------------------------------------------------------
    def evaluate_all(self, batch: int = 512) -> List[float]:
        """Per-client test accuracy, all clients per test chunk in one call."""
        n = self.test_x.shape[0]
        correct = np.zeros((self.n_clients,), np.int64)
        for i in range(0, n, batch):
            lg = self._eval_batched(self.params, self.test_x[i:i + batch])
            hits = jnp.sum(jnp.argmax(lg, -1)
                           == self.test_y[None, i:i + batch], axis=-1)
            correct += np.asarray(hits)
        return (correct / n).tolist()
