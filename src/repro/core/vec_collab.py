"""Vectorized multi-client engine: all clients advance in ONE jitted step.

The paper's headline claim is that CoRS "is scalable with the number of
clients"; the sequential `CollabTrainer` oracle steps clients in a Python
loop (cost linear in N, one dispatch per client per phase). This engine
stacks homogeneous clients' params / Adam moments / data along a leading
client axis and runs the whole round — relay sampling, local updates,
uploads, server merge — as a single `jax.vmap`'d jitted function over that
axis, against the same fixed-shape relay state the sequential path uses.
Given the same seeds and equal-size partitions the two engines evolve
identical relay state and near-identical weights (see
tests/test_vec_collab.py and tests/test_relay_policies.py), but the
vectorized round is one XLA program instead of O(N) Python dispatches.

Relay policy: the server side is pluggable (`repro.relay`): `flat` (the
seed ring, bit-compatible), `per_class` (the paper's exact per-class buffer
layout) or `staleness` (exp(-λ·age) Gumbel-top-k sampling). The policy's
pure functions are closed over by the jitted round step, so swapping
policies swaps ONE compiled program, not the engine.

Participation: a `ParticipationSchedule` (repro.relay.participation) emits
a per-round boolean client mask. Schedules with a static participant count
k (uniform_k, cyclic) run COMPACTED: the step gathers the k participants
into a (k, ...) block, so a k=N/4 round costs ~1/4 of a full round —
real savings, not just masking. Variable-count schedules (bernoulli_p) and
the mesh path run full-width and mask: absent clients' params/opt are
frozen via `where`, their uploads zero-weighted, and the ring append drops
their rows without consuming slots. Either way there is exactly one jitted
round step per (policy, schedule) — the mask and gather indices are traced
arguments of fixed shape, so participation never retraces.

Device scaling is PLACEMENT-DRIVEN (repro.relay.placement): pass a mesh
with a "clients" axis (`sharding.client_mesh`, via `FleetConfig.mesh`) and
the SAME traced round body is jitted with in/out shardings resolved from
the state classes' placement declarations — client-resident leaves
(params, opt, data, pending uploads) are CLIENT_SHARDED over the mesh
axis, relay/history state is REPLICATED per `policy.out_spec` /
`events.out_spec` / `history.out_spec` — and GSPMD inserts the
collectives. The one cross-device exchange per round is
`placement.exchange` on the upload payload (the CLIENT_SHARDED ->
REPLICATED constraint right before the relay append/merge, which lowers
to the observation all-gather + the paper's O(C·d') prototype
all-reduce). There are no mesh branches in the round body, so every fleet
composition — async event log, download-lag history, hetero buckets,
static-k compaction — runs on the mesh through the same code path that
runs off it, and off-mesh bit-compatibility is structural.

Heterogeneous-architecture fleets (different client models, a CoRS selling
point) run BUCKETED: clients are grouped into stackable buckets by
`client_lib.bucketize` (same ClientSpec AND same param shapes), each bucket
gets its own jitted vmapped step (`make_bucket_update_step`), and all
buckets share ONE relay state. CoRS only couples clients through the
(C, d') representation pool — no weights cross the boundary — so the relay
is the only cross-bucket synchronization point. The round is synchronous:

  phase 1-3a  every bucket's downlink samples teachers from the SAME
              round-start relay state, then updates + computes uploads,
              independently per bucket (one dispatch per bucket, not per
              client);
  phase 3b    `make_relay_commit` appends all buckets' observation rows in
              bucket order (= the order the sequential oracle uploads in,
              see core/collab.py) and runs ONE prototype merge.

The per-round key schedule is the oracle's `collab.round_keys`, indexed by
ORIGINAL client id and sliced per bucket, so the sequential oracle remains
the bit-exact reference for ring bookkeeping under any bucket mix
(tests/test_hetero_bucketed.py). On a mesh, each bucket's stack is
CLIENT_SHARDED over the same client axis (GSPMD pads non-divisible bucket
sizes) and the shared commit is the exchange point. Static-k compaction
stays homogeneous-only: bucket participant counts vary per round even
under fixed-k schedules, and per-bucket stacks have different shapes.

Asynchrony: pass `clock` (a repro.sim ClockModel spec) and uploads commit
LATE through the event-ordered relay log (repro.relay.events): a round-r
upload with commit delay d <= D_max parks in a fixed-shape pending buffer
(N, D_max, ...) and is appended — in event order, stamped with its birth
clock — in round r+d, all inside ONE jitted async round step (homogeneous)
or the shared jitted async commit (bucketed). Teachers are always sampled
from the round-start COMMITTED state (the client's last sync; in-flight
uploads are invisible). The commit set decouples from the participant set,
so the async path runs full-width (on a mesh the pending buffer is
CLIENT_SHARDED and the commit payload is the round's one exchange);
`D_max = 0` keeps today's synchronous fast paths bit-identically. The
sequential oracle replays the identical event order host-side and stays
the bit-exact reference (tests/test_async_relay.py).

Download lag: pass `download_clock` (the same `repro.sim` spec machinery,
independent seed fold) and every client reads its teachers AND global
prototypes from a snapshot `d(client, t)` rounds staler than its
round-start sync — what its round-`t − d` self would have read fresh
(d = 0 is the round-start state) — the stale-sync half of asynchrony,
modeled by a bounded history ring of the last `H_max = d_max + 1` relay
states (repro.relay.history). The ring
is threaded through the SAME jitted round step: per-client snapshot reads
are dynamic indices into the history axis (one batched gather, fused with
the teacher-row gather) and the post-merge push happens at the end of the
step, with `H_max` static and the per-round delay vector traced — one
compile per (policy, schedule, clock spec), ever. Upload lag composes:
under both clocks a client can distill against a stale snapshot while its
own upload is still in flight, and because slot age is clock-derived
(`clock − stamp`), the ages it sees are the snapshot's own — a stale
download is automatically older by the time it is read. `H_max = 1` (or
no download clock) is bit-identical to today's engines; the sequential
oracle replays the ring host-side (tests/test_download_lag.py). On a
mesh the ring is REPLICATED (history.out_spec) and the per-client stale
reads stay local gathers — no extra collective.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs, relay as relay_lib, sim
from repro.core import baselines, client as client_lib, collab, comm, \
    prototypes
from repro.optim import adam_init
from repro.relay import placement
from repro.relay.participation import bcast_mask as _bcast, freeze_absent
from repro.types import CollabConfig, TrainConfig, resolve_fleet


def _stack(trees: Sequence[Any]):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


# ---------------------------------------------------------------------------
# Reusable round-phase builders, parameterized by ClientSpec. Both the fused
# homogeneous round step and the per-bucket heterogeneous steps are composed
# from these, so the phase semantics exist in exactly one place.
# ---------------------------------------------------------------------------
def make_teacher_phase(policy: relay_lib.RelayPolicy, ccfg: CollabConfig,
                       lagged: bool = False):
    """Phase 1 (downlink): vmapped teacher sampling from the relay buffers
    for relay modes, a broadcast no-op teacher otherwise. Returns
    `teachers(rstate, ids, relay_ks) -> teacher pytree (k, ...)`.

    `lagged=True` is the download-lag variant: `teachers(hist, ids,
    relay_ks, dl) `samples client i's teachers (and global prototypes)
    from `history.read_at(hist, dl[i])` — its own post-merge snapshot from
    dl[i] rounds ago. The per-client dynamic index into the history axis
    happens INSIDE the vmapped sample, so it lowers to one batched gather
    that XLA fuses with the teacher-row gather (no per-client state
    copies), and `dl` is a traced argument — lag patterns never retrace."""
    mode = ccfg.mode
    m_down = max(1, ccfg.m_down)

    if lagged:
        assert mode in ("cors", "fd"), mode

        def teachers_lagged(hist, ids, relay_ks, dl):
            return jax.vmap(
                lambda i, k, d: policy.sample_teacher(
                    relay_lib.history.read_at(hist, d), i, m_down, k))(
                        ids, relay_ks, dl)

        return teachers_lagged

    def teachers(rstate, ids, relay_ks):
        if mode in ("cors", "fd"):
            return jax.vmap(
                lambda i, k: policy.sample_teacher(
                    rstate, i, m_down, k))(ids, relay_ks)
        et = client_lib.empty_teacher(ccfg)
        k_loc = ids.shape[0]
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (k_loc,) + a.shape), et)

    return teachers


def make_client_upload_phase(spec: client_lib.ClientSpec,
                             ccfg: CollabConfig):
    """Phase 3a, per-client form: vmapped `compute_uploads` with NO
    cross-client reduction — the pieces the async event log parks and
    commits per upload (relay/events.py). Returns `uploads_of(params,
    data_x, data_y, upl_ks, ids) -> dict(obs (k, m, C, d'), valid (k, C),
    psum (k, C, d'), pcnt (k, C), [lsum (k, C, C), lcnt (k, C) in FD
    mode], owner (k,) int32)`."""
    mode = ccfg.mode

    def uploads_of(p_s, dx, dy, upl_ks, ids_s):
        uploads = jax.vmap(
            lambda p, x, y, k: client_lib.compute_uploads(
                spec, p, x, y, ccfg, k))(p_s, dx, dy, upl_ks)
        out = {"obs": uploads["obs"], "valid": uploads["valid"],
               "psum": uploads["proto"].sum,
               "pcnt": uploads["proto"].count,
               "owner": ids_s.astype(jnp.int32)}
        if mode == "fd":
            out["lsum"] = uploads["logit_proto"].sum
            out["lcnt"] = uploads["logit_proto"].count
        return out

    return uploads_of


def make_upload_phase(spec: client_lib.ClientSpec, ccfg: CollabConfig,
                      policy: relay_lib.RelayPolicy = None):
    """Phase 3a (uplink, compute side): the per-client pieces reduced into
    relay-ready synchronous-append form. Returns `uploads_of(params,
    data_x, data_y, upl_ks, ids, mask) -> (proto, logit|None, obs_rows,
    valid_rows, owner_rows, row_mask)` where absent clients' prototype
    sums are zero-weighted and their observation rows masked out (dropped
    by the relay append WITHOUT consuming ring slots).

    A `policy` defining `reduce_uploads` (e.g. the sharded relay) owns the
    reduction instead: the same mask weights and per-client sums are
    segmented by owner rather than summed over the client axis. Policies
    without the hook (and `policy=None`) keep the traced program
    unchanged."""
    mode = ccfg.mode
    per_client = make_client_upload_phase(spec, ccfg)
    reduce = policy.reduce_uploads if policy is not None else None

    def uploads_of(p_s, dx, dy, upl_ks, ids_s, sub_mask):
        wf = sub_mask.astype(jnp.float32)
        u = per_client(p_s, dx, dy, upl_ks, ids_s)
        if reduce is None:
            proto = prototypes.ProtoState(
                jnp.sum(u["psum"] * wf[:, None, None], axis=0),
                jnp.sum(u["pcnt"] * wf[:, None], axis=0))
        else:
            proto = reduce(u["psum"], u["pcnt"], wf, u["owner"])
        logit = None
        if mode == "fd":
            logit = (prototypes.ProtoState(
                jnp.sum(u["lsum"] * wf[:, None, None], axis=0),
                jnp.sum(u["lcnt"] * wf[:, None], axis=0))
                if reduce is None
                else reduce(u["lsum"], u["lcnt"], wf, u["owner"]))
        m_real = u["obs"].shape[1]           # 0 when m_up == 0
        obs_rows = u["obs"].reshape(-1, *u["obs"].shape[2:])
        valid_rows = jnp.repeat(u["valid"], m_real, axis=0)
        owner_rows = jnp.repeat(ids_s, m_real)
        row_mask = jnp.repeat(sub_mask, m_real)
        return proto, logit, obs_rows, valid_rows, owner_rows, row_mask

    return uploads_of


def make_relay_commit(policy: relay_lib.RelayPolicy, lagged: bool = False,
                      mesh=None):
    """Phase 3b: the round's single relay write. `commit(rstate, payloads)`
    takes the per-bucket upload payloads (in bucket order), concatenates
    their observation rows, sums their prototype contributions, appends and
    runs ONE prototype merge. Appending the concatenation equals appending
    bucket-by-bucket: every policy's append writes rows in order and masked
    rows consume no slots, so per-bucket uploads COMPOSE. The bucket count
    and per-bucket row counts are fixed, so jitting this gives one trace —
    and zero per-round eager concat/merge dispatches — for the whole run.

    `lagged=True`: `commit(rstate, payloads, hist)` additionally pushes the
    post-merge state into the download-lag history ring and returns
    `(rstate, hist)` (the zero-participant round, which skips this commit
    entirely, pushes via a bare `history.push` in the engine instead).

    `mesh`: the concatenated payload is THE round's cross-device exchange
    (placement.exchange) — the buckets' client-sharded rows and summed
    prototypes become replicated right before the append/merge."""

    def commit(rstate, payloads, *lag):
        cat = lambda k: jnp.concatenate([p[k] for p in payloads])
        proto = prototypes.merge(*[p["proto"] for p in payloads])
        logit = (prototypes.merge(*[p["logit"] for p in payloads])
                 if payloads[0]["logit"] is not None else None)
        (proto, logit, obs_rows, valid_rows, owner_rows, row_mask) = \
            placement.exchange(
                (proto, logit, cat("obs_rows"), cat("valid_rows"),
                 cat("owner_rows"), cat("row_mask")), mesh)
        new = policy.append(rstate, obs_rows, valid_rows,
                            owner_rows, row_mask)
        new = policy.merge_round(new, proto, logit)
        if lagged:
            return new, relay_lib.history.push(lag[0], new)
        return new

    return commit


def _client_rep(mesh):
    """The two resolved shardings of the placement alphabet on `mesh`."""
    return (placement.resolve(placement.CLIENT_SHARDED, mesh),
            placement.resolve(placement.REPLICATED, mesh))


def make_async_round_step(spec: client_lib.ClientSpec, ccfg: CollabConfig,
                          tcfg: TrainConfig, policy: relay_lib.RelayPolicy,
                          lagged: bool = False, mesh=None, templates=None,
                          telemetry: bool = False):
    """The homogeneous ASYNC round step (bounded-delay uploads,
    relay/events.py): phases 1-2 exactly as the synchronous step, then ONE
    `events.commit_and_park` — commit every due event (pending uploads
    whose clock says "now" + this round's delay-0 uploads) in event order,
    park the rest. Full-width only: lateness decouples who trains from
    whose upload commits, so the static-k participant gather does not
    cover the commit set. `round_idx` and `delays` are traced arguments —
    one compile, ever.

    Returns a jitted `step(params, opt, rstate, pending, batches, data_x,
    data_y, ids, relay_ks, upd_ks, upl_ks, mask, delays, round_idx) ->
    (params, opt, rstate, pending, metrics)`.

    `lagged=True` composes upload lag with DOWNLOAD lag: the step takes
    two trailing args `(hist, dl)`, samples teachers from each client's
    `t − dl[i]` snapshot, pushes the post-merge state into the ring, and
    additionally returns the new history — so a stale download of a
    delayed commit is exactly as old as the two clocks say.

    `mesh` + `templates` (dict with "rstate"/"pending"[/"hist"] state
    examples): jit the SAME traced body with in/out shardings resolved
    from the placement declarations — client state and the pending buffer
    CLIENT_SHARDED, relay/history REPLICATED — and mark the commit payload
    as the round's one exchange (`commit_and_park(..., mesh=mesh)`).

    `telemetry=True` (a STATIC build flag, so the telemetry-off program is
    byte-identical to a telemetry-free build): append an in-jit
    `obs.RoundTelemetry` — REPLICATED on a mesh (obs.metrics.out_spec) —
    as the step's last output, computed from state the step already holds
    (round-start vs post-commit relay state, the pre-commit pending
    buffer's due events, this round's mask/delays)."""
    mode = ccfg.mode
    assert mode in ("cors", "fd"), mode
    local_update = client_lib.make_local_update_fn(spec, ccfg, tcfg)
    teachers = make_teacher_phase(policy, ccfg, lagged=lagged)
    per_client = make_client_upload_phase(spec, ccfg)

    def step(params, opt, rstate, pending, batches, data_x, data_y, ids,
             relay_ks, upd_ks, upl_ks, mask, delays, round_idx, *lag):
        rstate0, pending0 = rstate, pending
        # phases 1-2 — downlink from the COMMITTED state of the client's
        # last sync (round start, or dl[i] rounds earlier under download
        # lag; in-flight uploads are invisible either way) + local
        # updates; absent clients freeze
        with jax.named_scope("teacher_read"):
            teacher = (teachers(lag[0], ids, relay_ks, lag[1]) if lagged
                       else teachers(rstate, ids, relay_ks))
        with jax.named_scope("update"):
            new_p, new_o, metrics = jax.vmap(local_update)(
                params, opt, batches, teacher, upd_ks)
            p_s = freeze_absent(mask, new_p, params)
            o_s = freeze_absent(mask, new_o, opt)
            metrics = jax.tree.map(
                lambda m: jnp.where(_bcast(mask, m), m, 0.0), metrics)
        # phase 3 — the event log's single relay write (and, on a mesh,
        # the round's single cross-device exchange)
        with jax.named_scope("upload"):
            fresh = per_client(p_s, data_x, data_y, upl_ks, ids)
        with jax.named_scope("commit"):
            rstate, pending = relay_lib.events.commit_and_park(
                policy, rstate, pending, fresh, round_idx, delays, mask,
                mesh=mesh)
        tail = ()
        if telemetry:
            with jax.named_scope("telemetry"):
                tail = (obs.round_telemetry(
                    rstate0, rstate, mask.shape[0], mask=mask,
                    loss_parts=(metrics["total"],),
                    gnorm_parts=(metrics["grad_norm"],),
                    mask_parts=(mask,), pending=pending,
                    pending_pre=pending0, round_idx=round_idx,
                    delays=delays, dl=lag[1] if lagged else None),)
        if lagged:
            hist = relay_lib.history.push(lag[0], rstate)
            return (p_s, o_s, rstate, pending, hist, metrics) + tail
        return (p_s, o_s, rstate, pending, metrics) + tail

    if mesh is None:
        return jax.jit(step)
    cl, rep = _client_rep(mesh)
    rspec = placement.resolve(policy.out_spec(templates["rstate"]), mesh)
    pspec = placement.resolve(
        relay_lib.events.out_spec(templates["pending"]), mesh)
    in_sh = (cl, cl, rspec, pspec, cl, cl, cl, cl, cl, cl, cl, cl, cl, rep)
    out_sh = (cl, cl, rspec, pspec)
    if lagged:
        hspec = placement.resolve(
            relay_lib.history.out_spec(templates["hist"]), mesh)
        in_sh += (hspec, cl)
        out_sh += (hspec,)
    out_sh += (cl,) + ((rep,) if telemetry else ())
    return jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)


def make_async_relay_commit(policy: relay_lib.RelayPolicy,
                            lagged: bool = False, mesh=None):
    """Heterogeneous counterpart of `make_relay_commit` for the async
    engine: concatenate the buckets' PER-CLIENT payloads in bucket (=
    upload/event) order and run ONE `events.commit_and_park`. `delays` and
    `mask` arrive permuted to upload order, matching the concatenation and
    the pending buffer's upload-position indexing. `lagged=True` takes a
    trailing history arg, pushes the post-merge state (this commit runs
    EVERY round, so the ring advances even on no-commit rounds) and
    returns it. `mesh` marks the commit payload as the round's one
    cross-device exchange (see `events.commit_and_park`)."""

    def commit(rstate, pending, payloads, round_idx, delays, mask, *lag):
        keys = [k for k in payloads[0] if payloads[0][k] is not None]
        fresh = {k: jnp.concatenate([p[k] for p in payloads]) for k in keys}
        rstate, pending = relay_lib.events.commit_and_park(
            policy, rstate, pending, fresh, round_idx, delays, mask,
            mesh=mesh)
        if lagged:
            return rstate, pending, relay_lib.history.push(lag[0], rstate)
        return rstate, pending

    return commit


def make_bucket_update_step(spec: client_lib.ClientSpec, ccfg: CollabConfig,
                            tcfg: TrainConfig,
                            policy: relay_lib.RelayPolicy,
                            per_client_payload: bool = False,
                            lagged: bool = False):
    """One bucket's full-width masked round step against a FIXED relay
    state: downlink + local updates + upload payloads (phases 1-3a). The
    relay write (3b) is deliberately NOT here — the bucketed engine lets
    every bucket read the same round-start state and then commits all
    buckets' uploads in bucket order via `make_relay_commit` (synchronous)
    or `make_async_relay_commit` (bounded-delay event log, which needs the
    UNREDUCED per-client pieces — `per_client_payload=True`).

    Returns a jitted `step(params, opt, rstate, batches, data_x, data_y,
    ids, relay_ks, upd_ks, upl_ks, mask) -> (params, opt, metrics,
    payload)`; `payload` is None outside relay modes. The mask is a traced
    argument, so participation never retraces; one trace per bucket, ever.

    `lagged=True` (download lag): the `rstate` slot receives the shared
    history ring instead, plus a trailing `dl` arg — the bucket's clients
    read their own `t − dl[j]` snapshots. History is read-only here; the
    shared commit owns the push.
    """
    mode = ccfg.mode
    local_update = client_lib.make_local_update_fn(spec, ccfg, tcfg)
    teachers = make_teacher_phase(policy, ccfg, lagged=lagged)
    uploads_of = make_upload_phase(spec, ccfg, policy)
    uploads_per_client = make_client_upload_phase(spec, ccfg)

    def step(params, opt, rstate, batches, data_x, data_y, ids,
             relay_ks, upd_ks, upl_ks, mask, *lag):
        teacher = (teachers(rstate, ids, relay_ks, lag[0]) if lagged
                   else teachers(rstate, ids, relay_ks))
        new_p, new_o, metrics = jax.vmap(local_update)(
            params, opt, batches, teacher, upd_ks)
        p_s = freeze_absent(mask, new_p, params)
        o_s = freeze_absent(mask, new_o, opt)
        metrics = jax.tree.map(
            lambda m: jnp.where(_bcast(mask, m), m, 0.0), metrics)
        payload = None
        if mode in ("cors", "fd"):
            if per_client_payload:
                payload = uploads_per_client(p_s, data_x, data_y, upl_ks,
                                             ids)
            else:
                proto, logit, obs_rows, valid_rows, owner_rows, row_mask = \
                    uploads_of(p_s, data_x, data_y, upl_ks, ids, mask)
                payload = {"proto": proto, "logit": logit,
                           "obs_rows": obs_rows, "valid_rows": valid_rows,
                           "owner_rows": owner_rows, "row_mask": row_mask}
        return p_s, o_s, metrics, payload

    return jax.jit(step)


def make_eval_hits(spec: client_lib.ClientSpec):
    """Jitted stacked-client eval: logits for the whole client stack plus
    argmax/compare/reduce INSIDE the jit, so one test chunk costs one
    dispatch and returns a (k,) per-client hit-count vector (no eager
    argmax ops, no host sync per chunk)."""

    def hits(P, x, y):
        lg = jax.vmap(lambda p: spec.apply(p, x)[1])(P)
        return jnp.sum(jnp.argmax(lg, -1) == y[None], axis=-1)

    return jax.jit(hits)


@dataclass
class ClientBucket:
    """One stackable group of clients inside the bucketed engine: shared
    ClientSpec + param shapes, params/opt/data stacked along a leading axis
    of size len(ids), and the bucket's own jitted step/eval functions.
    `ids` are ORIGINAL client ids (ascending), used for relay owner tags,
    key-schedule slicing and participation-mask slicing."""
    spec: client_lib.ClientSpec
    ids: np.ndarray
    params: Any
    opt: Any
    batches: Dict
    data_x: jax.Array
    data_y: jax.Array
    step: Callable
    eval_fn: Callable


class VectorizedCollabTrainer:
    """Drop-in counterpart of `CollabTrainer` for any client fleet.

    Same constructor shape, `run_round` record schema, `ledger` accounting
    and `history`; `specs` may be a single ClientSpec or a sequence. Clients
    are grouped into stackable buckets (`client_lib.bucketize`); a
    homogeneous fleet is ONE bucket and runs the fused single-step fast
    path (static-k compaction, optional placement-sharded mesh), a mixed
    fleet runs one vmapped step per bucket around a shared relay. Client
    datasets are trimmed to the shortest partition within each bucket so
    they stack; pass equal-size partitions for exact parity with the
    oracle.

    The fleet (relay policy, participation schedule, upload/download
    clocks, mesh) is ONE `FleetConfig` passed as `fleet=`; the loose
    legacy kwargs still work for a release via the `resolve_fleet`
    deprecation shim.
    """

    def __init__(self,
                 specs: Union[client_lib.ClientSpec,
                              Sequence[client_lib.ClientSpec]],
                 params_list: Sequence[Any],
                 client_data: Sequence[Tuple[jax.Array, jax.Array]],
                 test_data: Tuple[jax.Array, jax.Array],
                 ccfg: CollabConfig, tcfg: TrainConfig, seed: int = 0,
                 fleet=None, mesh=None, policy=None, schedule=None,
                 clock=None, download_clock=None, telemetry=None):
        fleet = resolve_fleet(fleet, mesh=mesh, policy=policy,
                              schedule=schedule, clock=clock,
                              download_clock=download_clock)
        # Observability (repro.obs): in-jit RoundTelemetry is a STATIC
        # build flag on the round steps (off -> traced program unchanged);
        # sinks/tracing are host-side round-record plumbing.
        self.telemetry = obs.resolve(telemetry)
        self._telem = self.telemetry is not None and self.telemetry.metrics
        self._sink = (obs.JsonlWriter(self.telemetry.jsonl)
                      if self.telemetry and self.telemetry.jsonl else None)
        self._tracer = (obs.TraceRecorder(path=self.telemetry.trace,
                                          profile=self.telemetry.profile)
                        if self.telemetry and (self.telemetry.trace
                                               or self.telemetry.profile)
                        else None)
        self._span = self._tracer.span if self._tracer else obs.null_span
        if isinstance(specs, client_lib.ClientSpec):
            specs = [specs] * len(params_list)
        assert len(specs) == len(params_list) == len(client_data)
        self.ccfg, self.tcfg = ccfg, tcfg
        self.n_clients = N = len(params_list)
        self.mesh = mesh = fleet.mesh
        self.policy = relay_lib.get_policy(fleet.policy)
        self.clock = sim.get_clock(fleet.clock, seed=seed)
        # Streaming population (repro.sim.population): the cohort table
        # OWNS participation and seat indices carry EXTERNAL client ids.
        # Composition guards mirror the sequential oracle (core/collab.py)
        # exactly — rejected, not silently wrong.
        self.arrivals = sim.get_arrivals(fleet.arrivals)
        self._streaming = self.arrivals is not None
        if self._streaming:
            if fleet.participation is not None:
                raise ValueError(
                    "streaming arrivals own participation (the cohort "
                    "table picks k active seats per round); leave "
                    "FleetConfig.participation unset")
            if self.clock is not None and self.clock.d_max > 0:
                raise ValueError(
                    "streaming arrivals do not compose with an async "
                    "upload clock yet: the pending buffer is indexed by "
                    "upload position, which seat turnover reuses")
            if fleet.download_clock is not None:
                raise ValueError(
                    "streaming arrivals do not compose with download lag "
                    "yet: history snapshots hold evicted owners' rows")
            if ccfg.mode not in ("cors", "fd"):
                raise ValueError(
                    "streaming arrivals need a relay mode (cors | fd); "
                    f"mode={ccfg.mode!r} has no server to stream through")
            self._cohort = self.arrivals.table(N)
            self.schedule = None
            self._evict = jax.jit(self.policy.evict_owners)
        else:
            self._cohort = None
            self.schedule = relay_lib.get_schedule(fleet.participation,
                                                   seed=seed,
                                                   clock=self.clock)
        # Asynchrony (bounded-delay uploads, relay/events.py) only touches
        # relay commits, so only relay modes run the async path; a D_max=0
        # clock IS the synchronous fleet and keeps today's fast paths
        # (static-k compaction) — and composes with a mesh either way: the
        # pending buffer is CLIENT_SHARDED (events.out_spec) and the
        # commit payload is the round's one exchange.
        self._async = (self.clock is not None and self.clock.d_max > 0
                       and ccfg.mode in ("cors", "fd"))
        # Download lag (relay/history.py): only relay modes download, so
        # only they carry the snapshot ring. Binding ANY download clock
        # (even d_max=0, i.e. H_max=1) routes through the history
        # machinery — the bit-compat probe the tests use. The ring is
        # REPLICATED on a mesh (history.out_spec); stale reads stay local.
        self.dl_clock = sim.get_download_clock(fleet.download_clock,
                                               seed=seed)
        self._lagged = (self.dl_clock is not None
                        and ccfg.mode in ("cors", "fd"))
        buckets = client_lib.bucketize(specs, params_list)
        self.bucket_ids: List[List[int]] = [ids for _, ids in buckets]
        self.hetero = len(buckets) > 1
        if self._streaming and self.hetero:
            raise ValueError(
                "streaming arrivals currently require a homogeneous "
                "fleet (seats are interchangeable); got "
                f"{len(buckets)} client buckets")
        if self.hetero and ccfg.mode == "fedavg":
            raise ValueError(
                "FedAvg averages whole weight vectors, which needs one "
                f"shared architecture; got {len(buckets)} distinct "
                "(spec, param-shape) buckets. Heterogeneous fleets only "
                "make sense in representation-coupled modes "
                "('cors'/'fd') or independently ('il').")

        if mesh is not None and N % mesh.shape[placement.CLIENT_AXIS]:
            raise ValueError(
                f"FleetConfig.mesh: the fleet's client axis (N={N}) must "
                f"divide the mesh's '{placement.CLIENT_AXIS}' axis "
                f"({mesh.shape[placement.CLIENT_AXIS]} devices). "
                "CLIENT_SHARDED state at rest (the client stacks, the "
                "async pending buffer) must materialize its sharding, and "
                "jax arrays cannot hold an uneven NamedSharding — GSPMD "
                "only pads values internal to a jit (which is why an "
                "uneven static-k block or hetero BUCKET is fine). Pad the "
                "fleet or use a device count that divides it.")
        self.relay_state = self.policy.init_state(
            ccfg, ccfg.d_feature, seed, n_clients=N)
        if mesh is not None:
            # commit the initial state to its declared placement so the
            # first round starts where every later round ends
            self.relay_state = jax.device_put(
                self.relay_state,
                placement.resolve(self.policy.out_spec(self.relay_state),
                                  mesh))
        self.test_x, self.test_y = (jnp.asarray(test_data[0]),
                                    jnp.asarray(test_data[1]))
        self.ledger = comm.CommLedger()
        self.key = jax.random.PRNGKey(seed)
        self.history: List[Dict] = []
        # Relay-write (= event) order: upload position u -> client id.
        # Bucket by bucket, client-id order within — identity for
        # homogeneous fleets. The pending buffer is indexed by u.
        self._upload_order = [i for _, ids in buckets for i in ids]
        if self._async:
            self.pending = relay_lib.events.init_pending(
                N, self.clock.d_max, ccfg.m_up, ccfg.num_classes,
                ccfg.d_feature, fd=(ccfg.mode == "fd"))
            if mesh is not None:
                self.pending = jax.device_put(
                    self.pending,
                    placement.resolve(
                        relay_lib.events.out_spec(self.pending), mesh))
            self._commit_mirror = relay_lib.events.CommitMirror()
        if self._lagged:
            self._h_max = self.dl_clock.d_max + 1
            self.hist = relay_lib.history.init(self.relay_state, self._h_max)
            if mesh is not None:
                self.hist = jax.device_put(
                    self.hist,
                    placement.resolve(
                        relay_lib.history.out_spec(self.hist), mesh))
            # bare push for rounds whose relay commit is skipped entirely
            # (zero-participant synchronous bucketed rounds): the ring
            # still advances with the (unchanged) post-round state.
            self._hist_push = jax.jit(relay_lib.history.push)

        if self.hetero:
            self._init_bucketed(buckets, params_list, client_data)
            return

        # -- homogeneous fast path: ONE bucket, fused round step ----------
        self.spec = specs[0]
        self.data_x, self.data_y, self.batches, self.params, self.opt_state \
            = self._stack_clients(params_list, client_data)
        if mesh is not None:
            # commit the client stacks to their placement up front: round 0
            # then presents the same (sharding, committed) signature as
            # every later round, keeping the jit fastpath single-entry
            # (the compile-once contract the tests pin)
            cl = placement.resolve(placement.CLIENT_SHARDED, mesh)
            (self.data_x, self.data_y, self.batches, self.params,
             self.opt_state) = jax.device_put(
                (self.data_x, self.data_y, self.batches, self.params,
                 self.opt_state), cl)

        # Compaction: only when the schedule's per-round count is static,
        # and only synchronously (lateness decouples who trains from whose
        # upload commits, so the participant gather does not cover the
        # commit set — the async step runs full-width). On a mesh the
        # compacted (k, ...) block is client-sharded like the full stack;
        # GSPMD pads non-divisible k.
        # Streaming cohorts run full-width: the seat-id vector is traced
        # and participation varies with the active-seat count.
        fixed_k = (self.schedule.fixed_k if self.schedule is not None
                   else None)
        self._k_active = (fixed_k if (fixed_k is not None
                                      and not self._async)
                          else N)
        self._round_step = (
            make_async_round_step(
                self.spec, ccfg, tcfg, self.policy, lagged=self._lagged,
                mesh=mesh,
                templates={"rstate": self.relay_state,
                           "pending": self.pending,
                           "hist": self.hist if self._lagged else None},
                telemetry=self._telem)
            if self._async else self._make_round_step())
        self._eval_hits = make_eval_hits(self.spec)

    # ------------------------------------------------------------------
    def _stack_clients(self, params_list, client_data):
        """Stack a stackable client group: trimmed data, batched views,
        params and fresh Adam state, all with a leading client axis."""
        n_common = min(x.shape[0] for x, _ in client_data)
        data_x = jnp.stack([jnp.asarray(x[:n_common])
                            for x, _ in client_data])
        data_y = jnp.stack([jnp.asarray(y[:n_common])
                            for _, y in client_data])
        k = len(params_list)
        bs = self.tcfg.batch_size
        nb = n_common // bs
        batches = {
            "x": data_x[:, :nb * bs].reshape(
                k, nb, bs, *data_x.shape[2:]),
            "y": data_y[:, :nb * bs].reshape(k, nb, bs)}
        params = _stack(params_list)
        opt = _stack([adam_init(p) for p in params_list])
        return data_x, data_y, batches, params, opt

    def _init_bucketed(self, buckets, params_list, client_data):
        """Build the per-bucket engine: one ClientBucket (stacked state +
        jitted step) per stackable group, a shared jitted relay commit, and
        the client-id -> (bucket, slot) map."""
        self.spec = None
        self.buckets: List[ClientBucket] = []
        self._client_slot: Dict[int, Tuple[int, int]] = {}
        for b, (spec, ids) in enumerate(buckets):
            data_x, data_y, batches, params, opt = self._stack_clients(
                [params_list[i] for i in ids],
                [client_data[i] for i in ids])
            if self.mesh is not None:
                # each bucket's stack is client-sharded over the SAME mesh
                # axis; a bucket whose size does not divide the axis falls
                # back to replicated (an array at rest cannot hold the
                # uneven sharding GSPMD would pad inside a jit).
                # Committing the inputs here lets the per-bucket jit infer
                # its shardings — the shared commit is the exchange point
                even = len(ids) % self.mesh.shape[placement.CLIENT_AXIS] == 0
                sh = placement.resolve(
                    placement.CLIENT_SHARDED if even else placement.REPLICATED,
                    self.mesh)
                data_x, data_y, batches, params, opt = jax.device_put(
                    (data_x, data_y, batches, params, opt), sh)
            self.buckets.append(ClientBucket(
                spec=spec, ids=np.asarray(ids, np.int64), params=params,
                opt=opt, batches=batches, data_x=data_x, data_y=data_y,
                step=make_bucket_update_step(
                    spec, self.ccfg, self.tcfg, self.policy,
                    per_client_payload=self._async,
                    lagged=self._lagged),
                eval_fn=make_eval_hits(spec)))
            for j, i in enumerate(ids):
                self._client_slot[i] = (b, j)
        self._relay_commit = jax.jit(
            make_async_relay_commit(self.policy, lagged=self._lagged,
                                    mesh=self.mesh)
            if self._async
            else make_relay_commit(self.policy, lagged=self._lagged,
                                   mesh=self.mesh))
        if self._telem:
            # the bucketed round has no single step to fuse telemetry
            # into (one jit per bucket + the shared commit), so it runs
            # one extra small jitted summary after the commit
            self._telem_fn = obs.metrics.make_telemetry_fn(
                self.n_clients, asynchronous=self._async,
                lagged=self._lagged)

    # ------------------------------------------------------------------
    def client_params(self, i: int):
        """Unstacked view of client i's params (checkpointing / inspection)."""
        if self.hetero:
            b, j = self._client_slot[i]
            return jax.tree.map(lambda p: p[j], self.buckets[b].params)
        return jax.tree.map(lambda p: p[i], self.params)

    # ------------------------------------------------------------------
    def _make_round_step(self):
        spec, ccfg, tcfg = self.spec, self.ccfg, self.tcfg
        N, mesh, policy = self.n_clients, self.mesh, self.policy
        mode = ccfg.mode
        lagged = self._lagged
        telem = self._telem        # static: off -> the trace is unchanged
        local_update = client_lib.make_local_update_fn(spec, ccfg, tcfg)
        teachers = make_teacher_phase(policy, ccfg, lagged=lagged)
        uploads_of = make_upload_phase(spec, ccfg, policy)
        # Gather/scatter the participant block ONLY when it is a strict
        # subset: with k == N the idx is a runtime arange XLA cannot elide,
        # and the full-size gather + scatter-back of params/opt/batches
        # would tax every full-participation round for nothing.
        compact = self._k_active < N

        def round_core(params, opt, rstate, batches, data_x, data_y, ids,
                       relay_ks, upd_ks, upl_ks, mask, idx, *lag):
            # `lag` = (hist, dl) under a download clock: the snapshot ring
            # (REPLICATED) and this round's (N,) download delays, both
            # traced. The body is mesh-free — with a mesh, the SAME trace
            # is jitted under the placement-resolved shardings below and
            # GSPMD inserts the collectives at the exchange.
            hist, dl = lag if lagged else (None, None)
            rstate0 = rstate
            # phase 0 — participant gather: the round runs on the
            # idx-selected (k, ...) block (identity permutation under full
            # participation).
            if compact:
                take = lambda t: jax.tree.map(lambda a: a[idx], t)
                p_s, o_s, b_s = take(params), take(opt), take(batches)
                dx, dy, ids_s = data_x[idx], data_y[idx], ids[idx]
                rk, uk, ok = relay_ks[idx], upd_ks[idx], upl_ks[idx]
                sub_mask = mask[idx]
                dl_s = dl[idx] if lagged else None
            else:
                p_s, o_s, b_s = params, opt, batches
                dx, dy, ids_s = data_x, data_y, ids
                rk, uk, ok = relay_ks, upd_ks, upl_ks
                sub_mask = mask
                dl_s = dl
            wf = sub_mask.astype(jnp.float32)
            n_present = jnp.sum(wf)
            any_present = n_present > 0

            keep = lambda new, old: freeze_absent(sub_mask, new, old)

            # phase 1 — downlink (vmapped relay sampling from the buffers;
            # under download lag, from each client's own stale snapshot)
            with jax.named_scope("teacher_read"):
                teacher = (teachers(hist, ids_s, rk, dl_s) if lagged
                           else teachers(rstate, ids_s, rk))

            # phase 2 — all local updates in one vmap (Algorithm 2 × k)
            with jax.named_scope("update"):
                new_p, new_o, metrics = jax.vmap(local_update)(
                    p_s, o_s, b_s, teacher, uk)
                p_s, o_s = keep(new_p, p_s), keep(new_o, o_s)
                metrics = jax.tree.map(
                    lambda m: jnp.where(_bcast(sub_mask, m), m, 0.0),
                    metrics)

            # phase 3 — uplink + merge (Algorithm 1): absent clients'
            # prototype sums are zero-weighted and their observation rows
            # dropped from the ring WITHOUT consuming slots; a round with
            # zero participants leaves the relay state untouched.
            if mode in ("cors", "fd"):
                with jax.named_scope("upload"):
                    (proto, logit, obs_rows, valid_rows, owner_rows,
                     row_mask) = uploads_of(p_s, dx, dy, ok, ids_s,
                                            sub_mask)
                # THE cross-device exchange (relay/placement.py): the
                # upload payload becomes replicated here — GSPMD lowers it
                # to the observation all-gather + the paper's O(C·d')
                # prototype all-reduce. No-op off-mesh.
                with jax.named_scope("exchange"):
                    (proto, logit, obs_rows, valid_rows, owner_rows,
                     row_mask) = placement.exchange(
                        (proto, logit, obs_rows, valid_rows, owner_rows,
                         row_mask), mesh)
                with jax.named_scope("commit"):
                    new_rstate = policy.append(rstate, obs_rows,
                                               valid_rows, owner_rows,
                                               row_mask)
                    new_rstate = policy.merge_round(new_rstate, proto,
                                                    logit)
                    rstate = jax.tree.map(
                        lambda n, o: jnp.where(any_present, n, o),
                        new_rstate, rstate)

            if mode == "fedavg":
                denom = jnp.maximum(n_present, 1.0)

                def avg(p):
                    # the weight average is fedavg's exchange: summing over
                    # the (sharded) client axis and constraining the result
                    # replicated lowers to the model-size all-reduce
                    s = jnp.sum(p.astype(jnp.float32) * _bcast(wf, p), axis=0)
                    s = placement.exchange(s, mesh)
                    a = (s / denom).astype(p.dtype)
                    return jnp.where(_bcast(sub_mask, p),
                                     jnp.broadcast_to(a, p.shape), p)
                p_s = jax.tree.map(avg, p_s)

            # phase 4 — scatter the compacted block back into the stack
            if compact:
                put = lambda full, s: jax.tree.map(
                    lambda f, v: f.at[idx].set(v), full, s)
                params, opt = put(params, p_s), put(opt, o_s)
                metrics_full = jax.tree.map(
                    lambda m: jnp.zeros((N,) + m.shape[1:],
                                        m.dtype).at[idx].set(m), metrics)
            else:
                params, opt, metrics_full = p_s, o_s, metrics
            tail = ()
            if telem:
                # synchronous commit lag is always 0, so the commit hist
                # collapses to bin 0 = n_present (the oracle's commit-list
                # length); stale reads come from the full-width dl vector.
                with jax.named_scope("telemetry"):
                    tail = (obs.round_telemetry(
                        rstate0, rstate, N, mask=mask,
                        loss_parts=(metrics_full["total"],),
                        gnorm_parts=(metrics_full["grad_norm"],),
                        mask_parts=(mask,), dl=dl),)
            if lagged:
                # ring advance is UNCONDITIONAL (unlike the relay write):
                # a zero-participant round still snapshots the unchanged
                # state, so "d rounds ago" always means rounds, not merges.
                hist = relay_lib.history.push(hist, rstate)
                return (params, opt, rstate, hist, metrics_full) + tail
            return (params, opt, rstate, metrics_full) + tail

        if mesh is None:
            return jax.jit(round_core)

        # Placement-resolved shardings: the SAME round_core trace, jitted
        # with client state CLIENT_SHARDED and relay/history state at the
        # policy's declared placement. GSPMD partitions the body and the
        # only collectives are the ones the exchange implies.
        cl, rep = _client_rep(mesh)
        rspec = placement.resolve(
            policy.out_spec(self.relay_state), mesh)
        in_sh = (cl, cl, rspec, cl, cl, cl, cl, cl, cl, cl, cl, rep)
        out_sh = (cl, cl, rspec)
        if lagged:
            hspec = placement.resolve(
                relay_lib.history.out_spec(self.hist), mesh)
            in_sh += (hspec, cl)
            out_sh += (hspec,)
        out_sh += (cl,) + ((rep,) if telem else ())
        return jax.jit(round_core, in_shardings=in_sh,
                       out_shardings=out_sh)

    # ------------------------------------------------------------------
    def _round_commits(self, r: int, mask_np, delays_np):
        """The round's commit list [(birth, client), ...] — event order,
        identical to the sequential oracle's replay (host-side mirror of
        the device pending buffer for records and comm billing)."""
        mode = self.ccfg.mode
        if mode not in ("cors", "fd"):
            return [(r, int(i)) for i in np.nonzero(mask_np)[0]]
        if self._async:
            return self._commit_mirror.step(r, mask_np, delays_np,
                                            self._upload_order)
        return [(r, int(i)) for i in self._upload_order if mask_np[i]]

    def run_round(self) -> Dict:
        if self.hetero:
            return self._run_round_bucketed()
        ccfg, N = self.ccfg, self.n_clients
        mode = ccfg.mode
        r = len(self.history)
        # Same key schedule as the sequential oracle: keys for ALL N
        # clients regardless of participation (absent clients just never
        # consume theirs), so seq and vec stay equivalence-testable under
        # every schedule.
        self.key, relay_ks, upd_ks, upl_ks = collab.round_keys(self.key, N)
        if self._streaming:
            # Cohort view: mask over SEATS, external ids per seat (the
            # traced `ids` arg — seat turnover never retraces). LRU-evicted
            # owners' ring slots are invalidated BEFORE any read this
            # round, same order as the sequential oracle.
            view = self._cohort.round(r)
            mask_np = view.mask.copy()
            ids = jnp.asarray(view.seat_ids, jnp.int32)
            if view.evicted.size:
                with self._span("evict", round=r) as sp:
                    self.relay_state = self._evict(
                        self.relay_state,
                        jnp.asarray(view.evicted, jnp.int32))
                    sp.block(self.relay_state)
        else:
            mask_np = np.asarray(self.schedule.mask(r, N), bool)
            ids = jnp.arange(N, dtype=jnp.int32)
        present = np.nonzero(mask_np)[0]
        delays_np = (self.clock.delays(r, N) if self.clock is not None
                     else np.zeros((N,), np.int64))
        commits = self._round_commits(r, mask_np, delays_np)
        mask = jnp.asarray(mask_np)
        # Download lag: this round's (N,) snapshot ages, traced like the
        # upload delays — the lag pattern never retraces the step.
        lag = ((self.hist,
                jnp.asarray(self.dl_clock.delays(r, N), jnp.int32))
               if self._lagged else ())
        telem = None
        if self._async:
            # Full-width async step: round_idx/delays are traced, so the
            # event timeline never retraces; the pending buffer threads
            # through like the relay state.
            with self._span("round_step", round=r) as sp:
                out = sp.block(self._round_step(
                    self.params, self.opt_state, self.relay_state,
                    self.pending, self.batches, self.data_x, self.data_y,
                    ids, relay_ks, upd_ks, upl_ks, mask,
                    jnp.asarray(delays_np, jnp.int32),
                    jnp.asarray(r, jnp.int32), *lag))
            if self._telem:
                *out, telem = out
            if self._lagged:
                (self.params, self.opt_state, self.relay_state,
                 self.pending, self.hist, metrics) = out
            else:
                (self.params, self.opt_state, self.relay_state,
                 self.pending, metrics) = out
        else:
            if self._k_active < N:
                idx_np = present                 # static-k compaction
                assert idx_np.size == self._k_active, (
                    "schedule emitted a mask inconsistent with its fixed_k",
                    idx_np.size, self._k_active)
            else:
                idx_np = np.arange(N)
            idx = jnp.asarray(idx_np, jnp.int32)
            with self._span("round_step", round=r) as sp:
                out = sp.block(self._round_step(
                    self.params, self.opt_state, self.relay_state,
                    self.batches, self.data_x, self.data_y,
                    ids, relay_ks, upd_ks, upl_ks, mask, idx, *lag))
            if self._telem:
                *out, telem = out
            if self._lagged:
                (self.params, self.opt_state, self.relay_state, self.hist,
                 metrics) = out
            else:
                self.params, self.opt_state, self.relay_state, metrics = out

        up, down = comm.round_floats(
            mode, n_present=int(present.size), n_commit=len(commits),
            n_read=int(present.size) if self._lagged else None,
            C=ccfg.num_classes,
            d=ccfg.d_feature, m_up=ccfg.m_up, m_down=ccfg.m_down,
            model_size=(baselines.num_params(self.client_params(0))
                        if mode == "fedavg" else 0))
        self.ledger.log_round(up, down)

        metrics_np = jax.tree.map(np.asarray, metrics)
        metrics_all = [jax.tree.map(lambda v: float(v[i]), metrics_np)
                       for i in range(N)]
        return self._log_round(present, up, down, metrics_all, commits,
                               telemetry=telem)

    def _run_round_bucketed(self) -> Dict:
        """One synchronous round across all buckets: every bucket's step
        reads the SAME round-start relay state (downloads), then the shared
        commit writes all uploads in bucket order and merges once."""
        ccfg, N = self.ccfg, self.n_clients
        mode = ccfg.mode
        r = len(self.history)
        # The oracle's key schedule, indexed by ORIGINAL client id and
        # sliced per bucket — bucketing changes execution grouping, never
        # which randomness a client consumes.
        self.key, relay_ks, upd_ks, upl_ks = collab.round_keys(self.key, N)
        mask_np = np.asarray(self.schedule.mask(r, N), bool)
        present = np.nonzero(mask_np)[0]
        delays_np = (self.clock.delays(r, N) if self.clock is not None
                     else np.zeros((N,), np.int64))
        commits = self._round_commits(r, mask_np, delays_np)
        rstate0 = self.relay_state
        # Download lag: every bucket reads from the SAME shared history
        # ring, each client indexing its own stale snapshot; delays sliced
        # per bucket like the keys and the participation mask.
        dl_np = (np.asarray(self.dl_clock.delays(r, N), np.int64)
                 if self._lagged else None)
        pending0 = self.pending if self._async else None
        payloads, metrics_parts = [], []
        with self._span("bucket_steps", round=r) as sp:
            for b in self.buckets:
                ids_j = jnp.asarray(b.ids, jnp.int32)
                lag_b = ((jnp.asarray(dl_np[b.ids], jnp.int32),)
                         if self._lagged else ())
                b.params, b.opt, metrics, payload = b.step(
                    b.params, b.opt,
                    self.hist if self._lagged else rstate0,
                    b.batches, b.data_x, b.data_y,
                    ids_j, relay_ks[b.ids], upd_ks[b.ids], upl_ks[b.ids],
                    jnp.asarray(mask_np[b.ids]), *lag_b)
                metrics_parts.append(metrics)
                payloads.append(payload)
            sp.block(metrics_parts)

        hist_lag = (self.hist,) if self._lagged else ()
        with self._span("commit", round=r) as sp:
            if self._async:
                # The shared commit runs EVERY round: pending uploads can
                # be due even when nobody trains (and it no-ops when the
                # commit set is empty). mask/delays permuted to upload
                # order, like the concatenated payloads and the pending
                # buffer.
                perm = self._upload_order
                out = self._relay_commit(
                    rstate0, self.pending, tuple(payloads),
                    jnp.asarray(r, jnp.int32),
                    jnp.asarray(delays_np[perm], jnp.int32),
                    jnp.asarray(mask_np[perm]), *hist_lag)
                if self._lagged:
                    self.relay_state, self.pending, self.hist = out
                else:
                    self.relay_state, self.pending = out
            elif mode in ("cors", "fd") and present.size:
                out = self._relay_commit(rstate0, tuple(payloads),
                                         *hist_lag)
                if self._lagged:
                    self.relay_state, self.hist = out
                else:
                    self.relay_state = out
            elif self._lagged:
                # relay untouched this round, but the ring still advances
                self.hist = self._hist_push(self.hist, rstate0)
            sp.block(self.relay_state)

        telem = None
        if self._telem:
            # per-bucket loss/grad-norm parts in bucket order; the commit
            # quantities are permutation-invariant counts, so mask/delays
            # go in ORIGINAL client-id order (the pending buffer's due
            # events carry their own birth rounds)
            mask_parts = tuple(jnp.asarray(mask_np[b.ids])
                               for b in self.buckets)
            loss_parts = tuple(m["total"] for m in metrics_parts)
            gnorm_parts = tuple(m["grad_norm"] for m in metrics_parts)
            rest = ()
            if self._async:
                rest += (pending0, self.pending,
                         jnp.asarray(r, jnp.int32),
                         jnp.asarray(delays_np, jnp.int32))
            if self._lagged:
                rest += (jnp.asarray(dl_np, jnp.int32),)
            telem = self._telem_fn(
                rstate0, self.relay_state, jnp.asarray(mask_np),
                mask_parts, loss_parts, gnorm_parts, *rest)

        up, down = comm.round_floats(
            mode, n_present=int(present.size), n_commit=len(commits),
            n_read=int(present.size) if self._lagged else None,
            C=ccfg.num_classes,
            d=ccfg.d_feature, m_up=ccfg.m_up, m_down=ccfg.m_down)
        self.ledger.log_round(up, down)

        metrics_all: List[Dict] = [None] * N
        for b, metrics in zip(self.buckets, metrics_parts):
            m_np = jax.tree.map(np.asarray, metrics)
            for j, i in enumerate(b.ids):
                metrics_all[int(i)] = jax.tree.map(lambda v: float(v[j]),
                                                   m_np)
        return self._log_round(present, up, down, metrics_all, commits,
                               telemetry=telem)

    def _log_round(self, present, up, down, metrics_all, commits,
                   telemetry=None) -> Dict:
        with self._span("eval"):
            accs = self.evaluate_all()
        rec = {"round": len(self.history) + 1,
               "acc_mean": float(np.mean(accs)),
               "acc_std": float(np.std(accs)),
               "accs": accs,
               "metrics": metrics_all,
               "participants": present.tolist(),
               "commits": [[b, c] for b, c in commits],
               "comm_up": up, "comm_down": down}
        if telemetry is not None:
            rec["telemetry"] = obs.to_record(telemetry)
        self.history.append(rec)
        if self._sink is not None:
            self._sink.write(rec)
        if self._tracer is not None and self.telemetry.trace:
            self._tracer.write()
        return rec

    def run(self, rounds: int, log_every: int = 0) -> List[Dict]:
        for r in range(rounds):
            rec = self.run_round()
            if log_every and (r + 1) % log_every == 0:
                print(f"  round {rec['round']:3d} acc {rec['acc_mean']:.4f}"
                      f" ±{rec['acc_std']:.4f}")
        return self.history

    # ------------------------------------------------------------------
    def evaluate_all(self, batch: int = 512) -> List[float]:
        """Per-client test accuracy, all of a bucket's clients per test
        chunk in one call (homogeneous fleets are one bucket)."""
        n = self.test_x.shape[0]

        def stack_hits(fn, P):
            correct = 0                          # accumulate ON device —
            for i in range(0, n, batch):         # one sync per stack, not
                correct = correct + fn(          # one per chunk
                    P, self.test_x[i:i + batch], self.test_y[i:i + batch])
            return np.asarray(correct)

        if not self.hetero:
            return (stack_hits(self._eval_hits, self.params) / n).tolist()
        accs = np.zeros((self.n_clients,))
        for b in self.buckets:
            accs[b.ids] = stack_hits(b.eval_fn, b.params) / n
        return accs.tolist()
