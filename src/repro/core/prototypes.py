"""Per-class feature-representation statistics (the objects CoRS shares).

Two kinds of shared state (paper §3):
  - global prototypes  t̄^c : inter-client mean feature per class  (L_KD)
  - observations       t^c_m: intra-client averages of n_avg same-class
                              features                              (L_disc)

TPU adaptation: the per-class accumulation is a segment-sum; GPU code would
scatter-add, the MXU-native form is `one_hot(labels) @ features` (tiled in
kernels/proto_accum.py; the jnp path below is the oracle and the default).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class ProtoState(NamedTuple):
    """Running per-class sums. sum: (C, d') f32; count: (C,) f32."""
    sum: jax.Array
    count: jax.Array

    @property
    def num_classes(self) -> int:
        return self.sum.shape[0]


def init_state(num_classes: int, d_feature: int) -> ProtoState:
    return ProtoState(jnp.zeros((num_classes, d_feature), jnp.float32),
                      jnp.zeros((num_classes,), jnp.float32))


def accumulate(state: ProtoState, features, labels,
               use_kernel: bool = False) -> ProtoState:
    """features (n, d'); labels (n,) int. Adds per-class sums/counts."""
    C = state.num_classes
    feats = features.astype(jnp.float32)
    if use_kernel:
        from repro.kernels import ops
        s, c = ops.proto_accum(feats, labels, C)
    else:
        onehot = jax.nn.one_hot(labels, C, dtype=jnp.float32)  # (n, C)
        s = jnp.einsum("nc,nd->cd", onehot, feats)
        c = jnp.sum(onehot, axis=0)
    return ProtoState(state.sum + s, state.count + c)


def means(state: ProtoState, fallback: Optional[jax.Array] = None):
    """-> (C, d') per-class means; classes with zero count get `fallback`
    rows (default zeros)."""
    safe = jnp.maximum(state.count, 1.0)[:, None]
    m = state.sum / safe
    if fallback is not None:
        m = jnp.where(state.count[:, None] > 0, m, fallback)
    return m


def merge(*states: ProtoState) -> ProtoState:
    """Inter-client aggregation (the server's only computation, Alg. 1)."""
    return ProtoState(sum(s.sum for s in states),
                      sum(s.count for s in states))


def psum_merge(state: ProtoState, axis_name) -> ProtoState:
    """On-mesh aggregation over the client axis (relay == all-reduce)."""
    return ProtoState(jax.lax.psum(state.sum, axis_name),
                      jax.lax.psum(state.count, axis_name))


def observations(key, features, labels, num_classes: int, n_avg: int,
                 m_up: int = 1):
    """Paper's t^c_m: for each class c, m_up independent averages over
    n_avg same-class samples.

    features (n, d'); labels (n,). Classes with fewer than n_avg samples
    average whatever is present (mask-weighted); empty classes yield zero
    rows and a validity mask.

    Returns obs (m_up, C, d') f32, valid (C,) bool.
    """
    n, d = features.shape
    feats = features.astype(jnp.float32)
    onehot = jax.nn.one_hot(labels, num_classes, dtype=jnp.float32)  # (n,C)

    def one_obs(k):
        # random subset per class: weight each sample by a random priority,
        # keep the n_avg highest per class.
        prio = jax.random.uniform(k, (n,))
        # rank of each sample within its class (descending priority)
        order = jnp.argsort(-prio)
        ranked_onehot = onehot[order]                       # (n, C)
        rank_in_class = jnp.cumsum(ranked_onehot, axis=0) * ranked_onehot
        keep = (rank_in_class > 0) & (rank_in_class <= n_avg)  # (n, C)
        w = keep.astype(jnp.float32)
        s = jnp.einsum("nc,nd->cd", w, feats[order])
        cnt = jnp.maximum(jnp.sum(w, axis=0), 1.0)
        return s / cnt[:, None]

    keys = jax.random.split(key, m_up)
    obs = jax.vmap(one_obs)(keys)                           # (m_up, C, d')
    valid = jnp.sum(onehot, axis=0) > 0
    return obs, valid
