"""Baselines the paper compares against (Table 1): FedAvg aggregation,
plus helpers shared by IL/CL (which are CollabTrainer modes with no comm)."""
from __future__ import annotations

from typing import Any, Sequence

import jax


def fedavg_aggregate(params_list: Sequence[Any], weights=None):
    """McMahan et al. 17: weight averaging. Homogeneous models required."""
    n = len(params_list)
    if weights is None:
        weights = [1.0 / n] * n
    return jax.tree.map(
        lambda *ps: sum(w * p for w, p in zip(weights, ps)), *params_list)


def num_params(params) -> int:
    return sum(int(p.size) for p in jax.tree.leaves(params))
