from repro.kernels import disc_loss, flash_attention, ops, proto_accum, ref  # noqa: F401
