"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function mirrors the kernel's contract exactly; kernel tests sweep
shapes/dtypes and assert_allclose against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS = 1e-7


def flash_attention(q, k, v, *, causal: bool = True):
    """q (B,Sq,H,hd); k,v (B,Sk,G,hd); GQA-aware naive attention, f32 math."""
    B, Sq, H, hd = q.shape
    G = k.shape[2]
    Hr = H // G
    qf = (q.reshape(B, Sq, G, Hr, hd) * (hd ** -0.5)).astype(jnp.float32)
    s = jnp.einsum("bqghd,bkgd->bgqhk", qf, k.astype(jnp.float32))
    if causal:
        mask = jnp.tril(jnp.ones((Sq, k.shape[1]), bool))
        s = jnp.where(mask[None, None, :, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgqhk,bkgd->bgqhd", p, v.astype(jnp.float32))
    return o.transpose(0, 2, 1, 3, 4).reshape(B, Sq, H, hd).astype(q.dtype)


def proto_accum(features, labels, num_classes: int):
    """features (n, d) -> per-class sums (C, d) f32 and counts (C,) f32."""
    onehot = jax.nn.one_hot(labels, num_classes, dtype=jnp.float32)
    sums = jnp.einsum("nc,nd->cd", onehot, features.astype(jnp.float32))
    counts = jnp.sum(onehot, axis=0)
    return sums, counts


def disc_loss(student_logits, teacher_probs, labels, valid=None):
    """Per-sample CoRS discriminator loss (Eq. 7).

    student_logits (B, C); teacher_probs (M, C) already softmaxed;
    labels (B,) index into the M axis (observation m of class c sits at
    row c, so M == C in the paper's layout). Returns (B,) f32.
    """
    p = jax.nn.softmax(student_logits.astype(jnp.float32), axis=-1)
    h = jnp.clip(p @ teacher_probs.astype(jnp.float32).T, _EPS, 1.0 - _EPS)
    M = teacher_probs.shape[0]
    pos = jax.nn.one_hot(labels, M, dtype=jnp.float32)
    v = jnp.ones((M,), jnp.float32) if valid is None else valid.astype(jnp.float32)
    per_pair = -(pos * jnp.log(h) + (1.0 - pos) * jnp.log1p(-h)) * v[None, :]
    return jnp.sum(per_pair, axis=-1)
