"""Pallas TPU kernel: fused CoRS discriminator loss (Eq. 7) at vocab scale.

Computes, per student sample i:
    h[i, m] = < softmax(s_logits[i]), q[m] >        (q = teacher probs)
    loss[i] = -log h[i, y_i] - sum_{m != y_i, valid} log(1 - h[i, m])

without ever materializing softmax(s_logits) in HBM: the class axis C is
tiled; a flash-style running (max, denom, h_acc) rescale folds each class
tile into the unnormalized inner products. Grid (b_blocks, c_blocks), the
trailing class axis sequential; h_acc (block_b, M) lives in VMEM scratch and
the BCE reduce happens on the last class tile.

This is the LM-scale hot spot of the paper's objective: at C = 152k and
M = 1k observations, the naive path writes a (B, C) probability matrix per
loss term; the fused kernel keeps everything in VMEM tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_EPS = 1e-7
NEG_INF = -1e30


def _kernel(s_ref, q_ref, y_ref, v_ref, loss_ref, m_scr, z_scr, h_scr, *,
            block_b: int, block_c: int, M: int):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        z_scr[...] = jnp.zeros_like(z_scr)
        h_scr[...] = jnp.zeros_like(h_scr)

    s = s_ref[...].astype(jnp.float32)                       # (bb, bc)
    q = q_ref[...].astype(jnp.float32)                       # (M, bc)
    m_prev = m_scr[...]                                      # (bb, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p_un = jnp.exp(s - m_new)                                # unnormalized
    alpha = jnp.exp(m_prev - m_new)
    z_scr[...] = z_scr[...] * alpha + jnp.sum(p_un, axis=1, keepdims=True)
    h_scr[...] = h_scr[...] * alpha + jax.lax.dot_general(
        p_un, q, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                  # (bb, M)
    m_scr[...] = m_new

    @pl.when(ci == nc - 1)
    def _finish():
        h = h_scr[...] / jnp.maximum(z_scr[...], 1e-30)      # (bb, M)
        h = jnp.clip(h, _EPS, 1.0 - _EPS)
        y = y_ref[0]                                         # (bb,)
        valid = v_ref[0].astype(jnp.float32)                 # (M,)
        mids = jax.lax.broadcasted_iota(jnp.int32, (block_b, M), 1)
        pos = (mids == y[:, None]).astype(jnp.float32)
        per = -(pos * jnp.log(h) + (1.0 - pos) * jnp.log1p(-h))
        per = per * valid[None, :]
        loss_ref[...] = jnp.sum(per, axis=1, keepdims=True)


def disc_loss(student_logits, teacher_probs, labels, valid=None, *,
              block_b: int = 256, block_c: int = 512,
              interpret: bool = False):
    """student_logits (B, C); teacher_probs (M, C) (rows softmaxed);
    labels (B,) int32 in [0, M); valid (M,) bool. -> per-sample loss (B,)."""
    B, C = student_logits.shape
    M = teacher_probs.shape[0]
    if valid is None:
        valid = jnp.ones((M,), jnp.float32)
    block_b = min(block_b, B)
    block_c = min(block_c, C)
    b_pad = (-B) % block_b
    c_pad = (-C) % block_c
    if b_pad:
        student_logits = jnp.pad(student_logits, ((0, b_pad), (0, 0)))
        labels = jnp.pad(labels, (0, b_pad))
    if c_pad:
        # pad class axis with -inf student logits / zero teacher probs:
        # contributes nothing to softmax or inner products
        student_logits = jnp.pad(student_logits, ((0, 0), (0, c_pad)),
                                 constant_values=NEG_INF)
        teacher_probs = jnp.pad(teacher_probs, ((0, 0), (0, c_pad)))
    Bp, Cp = student_logits.shape
    labels = labels.astype(jnp.int32)

    grid = (Bp // block_b, Cp // block_c)
    kern = functools.partial(_kernel, block_b=block_b, block_c=block_c, M=M)
    loss = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_c), lambda bi, ci: (bi, ci)),
            pl.BlockSpec((M, block_c), lambda bi, ci: (0, ci)),
            pl.BlockSpec((1, block_b), lambda bi, ci: (0, bi)),
            pl.BlockSpec((1, M), lambda bi, ci: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, 1), lambda bi, ci: (bi, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, 1), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_b, 1), jnp.float32),
            pltpu.VMEM((block_b, 1), jnp.float32),
            pltpu.VMEM((block_b, M), jnp.float32),
        ],
        interpret=interpret,
    )(student_logits, teacher_probs, labels[None, :],
      valid.astype(jnp.float32)[None, :])
    return loss[:B, 0]
