"""Pallas TPU flash attention (causal, GQA-aware).

Grid (B, H, n_q, n_k): the trailing k axis is sequential on TPU, so the
online-softmax running state (m, l, acc) lives in VMEM scratch across k
iterations. Block shapes are MXU-aligned (block_q × block_k ≥ 128×128 for
full-size inputs; clamped for small test shapes). K/V BlockSpec index maps
fold the GQA head group (h → h // (H/G)) so KV is never materialized per
q-head.

VMEM budget per program ≈ (block_q + 2·block_k)·hd·4B + 3·block_q·(hd+2)·4B —
e.g. 128/128/128: ~0.4 MB, far under the ~16 MB/core VMEM of v5e.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            causal: bool, block_q: int, block_k: int, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)                  # (bk, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)
    if causal:
        qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                       (block_q, block_k), 0)
        kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                       (block_q, block_k), 1)
        s = jnp.where(qpos >= kpos, s, NEG_INF)

    m_prev = m_scr[...]                                  # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc = acc_scr[...] * alpha + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc

    @pl.when(ki == nk - 1)
    def _flush():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q (B,Sq,H,hd); k,v (B,Sk,G,hd) with H % G == 0. Returns (B,Sq,H,hd)."""
    B, Sq, H, hd = q.shape
    _, Sk, G, _ = k.shape
    assert H % G == 0
    rep = H // G
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0

    # (B,S,H,hd) -> (B,H,S,hd) for clean per-head blocking
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    grid = (B, H, Sq // block_q, Sk // block_k)
    kern = functools.partial(_kernel, causal=causal, block_q=block_q,
                             block_k=block_k, scale=hd ** -0.5)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, qi, ki, rep=rep: (b, h // rep, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, qi, ki, rep=rep: (b, h // rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
