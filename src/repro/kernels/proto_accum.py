"""Pallas TPU kernel: per-class feature accumulation (CoRS prototype stats).

GPU implementations scatter-add features into rows indexed by label; TPU has
no fast scatter, so the MXU-native reformulation builds a (block_n × block_c)
one-hot tile from the label block via iota-compare and accumulates
`one_hot.T @ features` — a dense matmul per tile. Grid (c_blocks, n_blocks):
the trailing n axis is sequential on TPU, so the (block_c, d) output tile
accumulates across n iterations in place.

Counts are the same contraction against a ones-vector (fused: we append a
ones column to the feature tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(labels_ref, feats_ref, sum_ref, cnt_ref, *, block_c: int,
            block_n: int):
    ci = pl.program_id(0)
    ni = pl.program_id(1)

    @pl.when(ni == 0)
    def _init():
        sum_ref[...] = jnp.zeros_like(sum_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    labels = labels_ref[0]                                   # (block_n,)
    feats = feats_ref[...].astype(jnp.float32)               # (block_n, d)
    class_ids = ci * block_c + jax.lax.broadcasted_iota(
        jnp.int32, (block_n, block_c), 1)
    onehot = (labels[:, None] == class_ids).astype(jnp.float32)
    sum_ref[...] += jax.lax.dot_general(
        onehot, feats, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                  # (block_c, d)
    cnt_ref[...] += jnp.sum(onehot, axis=0, keepdims=True).T  # (block_c, 1)


def proto_accum(features, labels, num_classes: int, *, block_n: int = 512,
                block_c: int = 256, interpret: bool = False):
    """features (n, d); labels (n,) int32 -> (sums (C, d) f32, counts (C,) f32).

    n is padded to block_n with an out-of-range label (contributes nowhere);
    C is padded to block_c and cropped.
    """
    n, d = features.shape
    block_n = min(block_n, max(8, n))
    block_c = min(block_c, num_classes)
    n_pad = (-n) % block_n
    c_pad = (-num_classes) % block_c
    C = num_classes + c_pad
    if n_pad:
        features = jnp.pad(features, ((0, n_pad), (0, 0)))
        labels = jnp.pad(labels, (0, n_pad), constant_values=-1)
    labels = labels.astype(jnp.int32)
    npad = n + n_pad

    grid = (C // block_c, npad // block_n)
    kern = functools.partial(_kernel, block_c=block_c, block_n=block_n)
    sums, cnts = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_n), lambda ci, ni: (0, ni)),
            pl.BlockSpec((block_n, d), lambda ci, ni: (ni, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_c, d), lambda ci, ni: (ci, 0)),
            pl.BlockSpec((block_c, 1), lambda ci, ni: (ci, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((C, d), jnp.float32),
                   jax.ShapeDtypeStruct((C, 1), jnp.float32)],
        interpret=interpret,
    )(labels[None, :], features)
    return sums[:num_classes], cnts[:num_classes, 0]
