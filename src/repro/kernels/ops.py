"""Jit'd public wrappers for the Pallas kernels.

Backend dispatch: on TPU the Pallas kernels run compiled; everywhere else
(this CPU container, dry-run lowering) they run via the pure-jnp oracles in
ref.py (identical math), or in interpret mode when `interpret=True` is forced
(kernel correctness tests). This keeps `use_kernel=True` call sites portable.
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels import disc_loss as _dl
from repro.kernels import flash_attention as _fa
from repro.kernels import proto_accum as _pa
from repro.kernels import ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("causal", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, interpret: bool = False):
    if interpret or _on_tpu():
        return _fa.flash_attention(q, k, v, causal=causal,
                                   interpret=interpret or not _on_tpu())
    return ref.flash_attention(q, k, v, causal=causal)


@partial(jax.jit, static_argnames=("num_classes", "interpret"))
def proto_accum(features, labels, num_classes: int, *,
                interpret: bool = False):
    if interpret or _on_tpu():
        return _pa.proto_accum(features, labels, num_classes,
                               interpret=interpret or not _on_tpu())
    return ref.proto_accum(features, labels, num_classes)


@partial(jax.jit, static_argnames=("interpret",))
def disc_loss(student_logits, teacher_probs, labels, valid=None, *,
              interpret: bool = False):
    if interpret or _on_tpu():
        return _dl.disc_loss(student_logits, teacher_probs, labels, valid,
                             interpret=interpret or not _on_tpu())
    return ref.disc_loss(student_logits, teacher_probs, labels, valid)
