from repro.optim.optim import (adam_init, adam_update, clip_by_global_norm,
                               cosine_schedule, sgd_init, sgd_update)  # noqa: F401
