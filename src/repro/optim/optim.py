"""Optimizers from scratch (no optax offline): Adam (paper's choice,
lr 1e-3), SGD(+momentum), cosine schedule, global-norm clipping.

Optimizer state mirrors the param pytree, so the same sharding specs apply
(FSDP shards Adam moments exactly like the params they track).
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def adam_init(params) -> AdamState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamState(jnp.zeros((), jnp.int32), zeros,
                     jax.tree.map(jnp.copy, zeros))


def adam_update(params, grads, state: AdamState, *, lr=1e-3, b1=0.9,
                b2=0.999, eps=1e-8, weight_decay=0.0):
    step = state.step + 1
    t = step.astype(jnp.float32)
    m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g.astype(jnp.float32),
                     state.m, grads)
    v = jax.tree.map(lambda vv, g: b2 * vv
                     + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                     state.v, grads)
    bc1 = 1 - b1 ** t
    bc2 = 1 - b2 ** t

    def upd(p, mm, vv):
        u = (mm / bc1) / (jnp.sqrt(vv / bc2) + eps)
        if weight_decay:
            u = u + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, AdamState(step, m, v)


class SgdState(NamedTuple):
    momentum: Any


def sgd_init(params, momentum: float = 0.0) -> SgdState:
    if momentum == 0.0:
        return SgdState(None)
    return SgdState(jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params))


def sgd_update(params, grads, state: SgdState, *, lr=1e-2, momentum=0.0):
    if momentum and state.momentum is not None:
        buf = jax.tree.map(lambda b, g: momentum * b + g.astype(jnp.float32),
                           state.momentum, grads)
        new = jax.tree.map(lambda p, b: (p.astype(jnp.float32)
                                         - lr * b).astype(p.dtype),
                           params, buf)
        return new, SgdState(buf)
    new = jax.tree.map(lambda p, g: (p.astype(jnp.float32)
                                     - lr * g.astype(jnp.float32)).astype(p.dtype),
                       params, grads)
    return new, state


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def cosine_schedule(step, *, base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1):
    t = step.astype(jnp.float32)
    warm = base_lr * t / jnp.maximum(1.0, float(warmup))
    prog = jnp.clip((t - warmup) / jnp.maximum(1.0, float(total - warmup)),
                    0.0, 1.0)
    cos = base_lr * (min_frac + (1 - min_frac) * 0.5
                     * (1 + jnp.cos(math.pi * prog)))
    return jnp.where(t < warmup, warm, cos)
