"""One spec-string grammar for every pluggable-component registry.

Relay policies ("staleness:0.5"), participation schedules ("uniform_k:8"),
upload clocks ("lognormal:4,1.5") and download clocks all accept the same
CLI-style shape:  NAME[:ARG[,ARG...]]  — but each module used to hand-roll
its own `partition(":")` + error message, so typos produced four different
diagnostics. `parse_spec` is the single tokenizer: it validates the NAME
against the registry the caller owns and raises ONE uniform error listing
the valid names, leaving argument semantics (types, defaults) to the
caller, which knows them.

Used by `repro.relay.get_policy`, `repro.relay.participation.get_schedule`
and `repro.sim.get_clock` / `get_download_clock`.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple


def parse_spec(spec, kind: str, names: Sequence[str],
               aliases: dict = None) -> Tuple[str, List[str]]:
    """Tokenize "NAME[:ARG[,ARG...]]" and validate NAME.

    spec:    the spec string (anything, str() is applied).
    kind:    what the registry holds, for the error message — e.g.
             "relay policy", "clock model", "participation schedule".
    names:   the registry's valid names.
    aliases: optional {alias: canonical} applied before validation.

    Returns (name, args) where args is the list of non-empty ","-split
    argument tokens (possibly empty). Raises ValueError with the uniform
    message  `unknown <kind>: <spec!r> (have <sorted names>)`  for an
    unknown name.
    """
    name, _, arg = str(spec).partition(":")
    if aliases and name in aliases:
        name = aliases[name]
    if name not in names:
        raise ValueError(
            f"unknown {kind}: {spec!r} (have {sorted(names)})")
    return name, [a for a in arg.split(",") if a] if arg else []
