"""Production meshes.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state — the dry-run must set
XLA_FLAGS=--xla_force_host_platform_device_count=512 before the first jax
device query, and smoke tests must keep seeing 1 device.

Axes:
  pod   — CoRS client axis (multi-pod only). Gradients are never reduced
          over it; the paper's representation exchange is its only traffic.
  data  — batch / FSDP axis.
  model — tensor-parallel axis.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU smoke runs (same axis names, sizes 1)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def describe(mesh) -> str:
    return "x".join(f"{n}:{mesh.shape[n]}" for n in mesh.axis_names)
