"""Distributed CoRS training step (the paper's technique on the mesh).

Client semantics on the mesh: parameters carry a leading `clients` axis
sharded over "pod"; `jax.vmap` over that axis gives every pod its own
client — per-client forward/backward/Adam with NO cross-pod gradient
traffic. The ONLY cross-pod collective in CoRS mode is the prototype
merge (mean of per-client per-class feature sums: an all-reduce of
(C, d'+1) floats), which is exactly the paper's communication claim, now
visible in the compiled HLO and measured by launch/roofline.py.

Baselines compile from the same builder:
  mode="fedavg": adds a per-step parameter all-reduce over clients (O(D)).
  mode="il"    : no cross-client collective at all.

Single-pod mesh: clients=1, same code (the vmap axis is size 1).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import sharding
from repro.core import losses, prototypes
from repro.relay import history as relay_history, placement
from repro.relay.participation import bcast_mask, freeze_absent
from repro.models import encdec, lm
from repro.optim import adam_init, adam_update
from repro.types import CollabConfig, ModelConfig, ShapeConfig


class TrainState(NamedTuple):
    params: Any
    opt: Any
    proto: prototypes.ProtoState
    step: jax.Array


# ---------------------------------------------------------------------------
# per-client loss
# ---------------------------------------------------------------------------
def _lm_outputs(cfg: ModelConfig, params, batch):
    if cfg.is_encoder_decoder:
        enc = encdec.encode(params, cfg, batch["frames"])
        out = encdec.decode_forward(params, cfg, batch["tokens"], enc,
                                    mode="train")
    else:
        out = lm.forward(params, cfg, batch, mode="train")
    return out


def _head_w(cfg: ModelConfig, params):
    w = params.get("lm_head")
    if w is None:
        w = params["embed"].T
    return w


def make_loss_fn(cfg: ModelConfig, ccfg: CollabConfig, *,
                 disc_tokens: int = 8192):
    """Per-client loss: Eq. (6) adapted to LM classification (class = next
    token). L_disc uses K sampled negatives on a token subsample (LM-scale
    adaptation, DESIGN.md §3)."""

    def loss_fn(params, batch, proto_means, key):
        out = _lm_outputs(cfg, params, batch)
        feats, logits = out["features"], out["logits"]
        labels = batch["labels"]
        l_ce = losses.ce_loss(logits, labels)
        metrics = {"ce": l_ce}
        total = l_ce + 0.01 * out["aux"]
        if ccfg.mode == "cors":
            l_kd = losses.kd_loss(feats, proto_means, labels)
            d = feats.shape[-1]
            f_flat = feats.reshape(-1, d)
            y_flat = labels.reshape(-1)
            T = min(disc_tokens, f_flat.shape[0])
            k1, _ = jax.random.split(key)
            l_disc = losses.disc_loss_sampled(
                k1, f_flat[:T], proto_means, y_flat[:T],
                _head_w(cfg, params), None,
                num_negatives=min(ccfg.num_negatives or 1023,
                                  cfg.vocab_size - 1),
                student_logits=logits.reshape(-1, cfg.vocab_size)[:T])
            total = total + ccfg.lambda_kd * l_kd + ccfg.lambda_disc * l_disc
            metrics.update(kd=l_kd, disc=l_disc)
        metrics["total"] = total
        return total, (metrics, feats, labels)

    return loss_fn


# ---------------------------------------------------------------------------
# the train step
# ---------------------------------------------------------------------------
def make_train_step(cfg: ModelConfig, ccfg: CollabConfig, *,
                    n_clients: int = 1, lr: float = 1e-3,
                    disc_tokens: int = 8192, client_axis: str = "pod",
                    sync_in_step: bool = True):
    """sync_in_step=False is the paper-faithful cadence: Algorithm 1
    exchanges prototypes once per ROUND, not per step — the step then only
    accumulates local stats and `make_round_sync()` does the merge. The
    default True folds the exchange into every step (worst case; what the
    naive port of the algorithm to synchronous SPMD would do)."""
    loss_fn = make_loss_fn(cfg, ccfg, disc_tokens=disc_tokens)
    C = cfg.vocab_size

    def client_step(params, opt, batch, proto_means, key):
        (_, (metrics, feats, labels)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, proto_means, key)
        params, opt = adam_update(params, grads, opt, lr=lr)
        # per-class feature stats of this client's batch (paper's uplink)
        stats = prototypes.accumulate(
            prototypes.init_state(C, feats.shape[-1]),
            feats.reshape(-1, feats.shape[-1]), labels.reshape(-1))
        return params, opt, stats, metrics

    def train_step(state: TrainState, batch, key, participation=None):
        """`participation`: optional (n_clients,) bool mask (see
        repro.relay.participation) — absent clients' params/opt freeze for
        the step, their per-class stats are zero-weighted in the merge, and
        the FedAvg baseline averages over present clients only. None (the
        default) is full participation and traces the identical program as
        before the mask existed."""
        proto_means = prototypes.means(state.proto)
        keys = jax.random.split(key, n_clients)
        params, opt, stats, metrics = jax.vmap(
            client_step, in_axes=(0, 0, 0, None, 0))(
                state.params, state.opt, batch, proto_means, keys)
        if participation is not None:
            wf = participation.astype(jnp.float32)
            params = freeze_absent(participation, params, state.params)
            opt = freeze_absent(participation, opt, state.opt)
            stats = prototypes.ProtoState(stats.sum * wf[:, None, None],
                                          stats.count * wf[:, None])
        if ccfg.mode == "fedavg":
            # baseline: per-step O(D) weight averaging across clients
            if participation is None:
                params = jax.tree.map(
                    lambda p: jnp.broadcast_to(jnp.mean(p, axis=0,
                                                        dtype=jnp.float32)
                                               .astype(p.dtype), p.shape),
                    params)
            else:
                n_eff = jnp.maximum(jnp.sum(wf), 1.0)

                def avg(p):
                    s = jnp.sum(p.astype(jnp.float32) * bcast_mask(wf, p),
                                axis=0) / n_eff
                    return jnp.broadcast_to(s.astype(p.dtype), p.shape)
                params = freeze_absent(participation,
                                       jax.tree.map(avg, params), params)
        if ccfg.mode in ("cors", "fd") and sync_in_step:
            # the paper's exchange: inter-client merge of per-class stats
            merged = prototypes.ProtoState(
                jnp.sum(stats.sum, axis=0), jnp.sum(stats.count, axis=0))
            decay = ccfg.proto_momentum or 1.0
            proto = prototypes.ProtoState(
                decay * state.proto.sum + merged.sum,
                decay * state.proto.count + merged.count)
        else:
            proto = state.proto
        if participation is None:
            metrics = jax.tree.map(lambda m: jnp.mean(m), metrics)
        else:
            # mean over PRESENT clients only — absent clients' updates were
            # discarded above, so their losses must not pollute the record
            metrics = jax.tree.map(
                lambda m: jnp.sum(m * wf) / jnp.maximum(jnp.sum(wf), 1.0),
                metrics)
        return TrainState(params, opt, proto, state.step + 1), metrics

    return train_step


def make_round_sync(ccfg: CollabConfig):
    """Per-round prototype exchange (paper Algorithm 1 cadence): merge the
    clients' accumulated stats into the shared ProtoState. Run once per
    round when the step was built with sync_in_step=False.

    Accepts one stats pytree per client-architecture BUCKET (each with its
    own leading client axis, as in core/vec_collab.py's bucketed engine):
    the proto state is the only thing heterogeneous buckets share, so a
    mixed fleet at LM scale is N_buckets `train_step`s + ONE round_sync
    over all their stats. A single homogeneous stack is the 1-bucket case.
    For straggler fleets whose stats commit LATE, use
    `make_async_round_sync` instead — it carries the clock state."""
    def round_sync(state: TrainState,
                   *bucket_stats: prototypes.ProtoState):
        merged = prototypes.merge(*[
            prototypes.ProtoState(jnp.sum(s.sum, axis=0),
                                  jnp.sum(s.count, axis=0))
            for s in bucket_stats])
        decay = ccfg.proto_momentum or 1.0
        return state._replace(proto=prototypes.ProtoState(
            decay * state.proto.sum + merged.sum,
            decay * state.proto.count + merged.count))
    return round_sync


def make_async_round_sync(ccfg: CollabConfig, d_max: int):
    """`make_round_sync` for a bounded-delay fleet (repro.sim clocks): a
    client's round-r stats with commit delay d join the SHARED prototype
    state in round r+d, not round r — the LM-scale counterpart of the
    collaborative engines' event-ordered relay (relay/events.py). The
    prototype merge is a sum, so late contributions are order-free; what
    must be carried across rounds is the clock state: a fixed-shape
    pending ProtoState of d_max future slots (slot j = stats due j+1
    rounds from now).

    Returns (init_pending, round_sync):
      init_pending(C, d')               -> pending ProtoState (d_max, C, ·)
      round_sync(state, pending, delays_and_stats...) -> (state, pending)
        where the varargs alternate (delays_b, stats_b) per bucket:
        delays_b (k_b,) int32 commit delays, stats_b the bucket's stacked
        per-client ProtoState. Pure/jittable; delays are traced, so
        straggler patterns never retrace. d_max = 0 degenerates to
        `make_round_sync` exactly (empty pending, everything commits now).
    """
    assert d_max >= 0, d_max

    def init_pending(C: int, d_feature: int) -> prototypes.ProtoState:
        return prototypes.ProtoState(
            jnp.zeros((d_max, C, d_feature), jnp.float32),
            jnp.zeros((d_max, C), jnp.float32))

    def round_sync(state: TrainState, pending: prototypes.ProtoState,
                   *delays_and_stats):
        assert len(delays_and_stats) % 2 == 0, \
            "pass (delays, stats) per bucket"
        # scatter every client's stats into its commit-delay slot:
        # sums[j] = sum of stats committing j rounds from now (j=0: now)
        C, d = state.proto.sum.shape
        sums = prototypes.ProtoState(jnp.zeros((d_max + 1, C, d)),
                                     jnp.zeros((d_max + 1, C)))
        for b in range(0, len(delays_and_stats), 2):
            delays = delays_and_stats[b].astype(jnp.int32)
            stats = delays_and_stats[b + 1]
            sums = prototypes.ProtoState(
                sums.sum.at[delays].add(stats.sum, mode="drop"),
                sums.count.at[delays].add(stats.count, mode="drop"))
        commit = prototypes.ProtoState(sums.sum[0], sums.count[0])
        if d_max > 0:
            commit = prototypes.ProtoState(commit.sum + pending.sum[0],
                                           commit.count + pending.count[0])
            # new_pending[j] (due j+1 rounds from now) = what was due j+2
            # rounds ago-relative (old pending[j+1]) + fresh stats with
            # delay j+1 (sums[j+1])
            shift = lambda a, fresh: jnp.concatenate(
                [a[1:], jnp.zeros_like(a[:1])]) + fresh[1:]
            pending = prototypes.ProtoState(
                shift(pending.sum, sums.sum),
                shift(pending.count, sums.count))
        decay = ccfg.proto_momentum or 1.0
        state = state._replace(proto=prototypes.ProtoState(
            decay * state.proto.sum + commit.sum,
            decay * state.proto.count + commit.count))
        return state, pending

    return init_pending, round_sync


def make_download_lag_round_sync(ccfg: CollabConfig, h_max: int):
    """`make_round_sync` for a fleet whose clients READ stale prototypes
    (repro.sim download clocks): the LM-scale counterpart of the relay
    history ring (relay/history.py). The merge itself is unchanged — what
    download lag needs is a bounded ring of the last `h_max` POST-MERGE
    ProtoStates, so a client syncing in round t with download delay d can
    be served the global prototypes as of round `t − d` instead of the
    fresh ones.

    Returns (init_history, round_sync, read_at):
      init_history(C, d')        -> History ring seeded with the empty
                                    ProtoState in every slot
      round_sync(state, hist, *bucket_stats) -> (state, hist): the plain
                                    merge, then push the post-merge proto
      read_at(hist, delays)      -> ProtoState(s) as of `delays` rounds
                                    ago; `delays` may be a scalar or — via
                                    vmap — a per-client vector, traced
                                    either way
    Pure/jittable below init. `h_max = 1` retains only the current
    post-merge proto, so delay-0 reads are bit-identical to
    `make_round_sync` alone."""
    assert h_max >= 1, h_max
    sync = make_round_sync(ccfg)

    def init_history(C: int, d_feature: int) -> relay_history.History:
        return relay_history.init(prototypes.init_state(C, d_feature),
                                  h_max)

    def round_sync(state: TrainState, hist: relay_history.History,
                   *bucket_stats: prototypes.ProtoState):
        state = sync(state, *bucket_stats)
        return state, relay_history.push(hist, state.proto)

    def read_at(hist: relay_history.History, delays) -> prototypes.ProtoState:
        if hasattr(delays, "ndim") and getattr(delays, "ndim", 0) > 0:
            return jax.vmap(lambda d: relay_history.read_at(hist, d))(delays)
        return relay_history.read_at(hist, delays)

    return init_history, round_sync, read_at


def proto_round_telemetry(prev: prototypes.ProtoState,
                          new: prototypes.ProtoState) -> Dict[str, Any]:
    """One round's prototype-level observability for the LM-scale path,
    which shares no relay ring with the collaborative engines and so gets
    the ProtoState-reducible subset of repro.obs's RoundTelemetry: the
    drift of the class means across the round's merge, the total absorbed
    stat mass, and class coverage. Host-side (a few (C, d') reductions per
    ROUND, not per step); JSON-safe for the same JSONL sink/report."""
    dm = prototypes.means(new) - prototypes.means(prev)
    return {
        "proto_drift": float(jnp.sqrt(jnp.sum(jnp.square(dm)))),
        "proto_mass": float(jnp.sum(new.count)),
        "classes_seen": int(jnp.sum(new.count > 0)),
    }


# ---------------------------------------------------------------------------
# state/batch construction (real arrays or ShapeDtypeStructs)
# ---------------------------------------------------------------------------
def init_state_shapes(cfg: ModelConfig, n_clients: int = 1):
    """abstract TrainState via eval_shape (no allocation — dry-run path)."""
    def init():
        key = jax.random.PRNGKey(0)
        if cfg.is_encoder_decoder:
            p = encdec.init_encdec(key, cfg)
        else:
            p = lm.init_lm(key, cfg)
        opt = adam_init(p)
        bc = lambda a: jnp.broadcast_to(a[None], (n_clients,) + a.shape)
        return TrainState(jax.tree.map(bc, p), jax.tree.map(bc, opt),
                          prototypes.init_state(cfg.vocab_size,
                                                cfg.d_feature),
                          jnp.zeros((), jnp.int32))
    return jax.eval_shape(init)


def init_state(cfg: ModelConfig, key, n_clients: int = 1) -> TrainState:
    if cfg.is_encoder_decoder:
        ps = [encdec.init_encdec(k, cfg)
              for k in jax.random.split(key, n_clients)]
    else:
        ps = [lm.init_lm(k, cfg) for k in jax.random.split(key, n_clients)]
    p = jax.tree.map(lambda *xs: jnp.stack(xs), *ps)
    opt = jax.tree.map(lambda *xs: jnp.stack(xs),
                       *[adam_init(pp) for pp in ps])
    return TrainState(p, opt,
                      prototypes.init_state(cfg.vocab_size, cfg.d_feature),
                      jnp.zeros((), jnp.int32))


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig,
                      n_clients: int = 1):
    """ShapeDtypeStructs for one global train batch."""
    Bc = shape.global_batch // n_clients
    S = shape.seq_len
    N = n_clients
    sds = jax.ShapeDtypeStruct
    batch: Dict[str, Any] = {
        "labels": sds((N, Bc, S), jnp.int32)}
    if cfg.input_kind == "tokens":
        batch["tokens"] = sds((N, Bc, S), jnp.int32)
    else:
        batch["embeddings"] = sds((N, Bc, S, cfg.d_model), jnp.dtype(cfg.dtype))
        if cfg.rope_kind == "mrope":
            batch["positions"] = sds((N, Bc, S, 3), jnp.int32)
    if cfg.is_encoder_decoder:
        batch["tokens"] = sds((N, Bc, S), jnp.int32)
        batch["frames"] = sds((N, Bc, cfg.encoder_seq, cfg.d_model),
                              jnp.dtype(cfg.dtype))
    return batch


# ---------------------------------------------------------------------------
# shardings
# ---------------------------------------------------------------------------
def _client_lead(mesh, n_clients: int):
    return "pod" if (n_clients > 1 and "pod" in mesh.axis_names) else None


def state_shardings(state_shapes, cfg: ModelConfig, mesh, n_clients: int = 1,
                    *, strategy: str = "tp"):
    """strategy:
      "tp"      (default) model axis = tensor parallel
      "dp_only" params replicated; the model axis becomes extra data
                parallelism — no per-layer activation all-reduces
      "zero1"   dp_only + Adam moments sharded over the flattened
                (data, model) axes (ZeRO-1: replicated-params memory without
                replicated-optimizer memory)"""
    lead = _client_lead(mesh, n_clients)
    flat_dp = sharding.dp_size(mesh) * sharding.axis_size(mesh, "model")

    def param_leaf(path, leaf):
        if strategy in ("dp_only", "zero1"):
            inner = [None] * (len(leaf.shape) - 1)
        else:
            inner = sharding.param_spec(path, leaf.shape[1:], mesh,
                                        fsdp=cfg.fsdp)
        return NamedSharding(mesh, P(lead, *inner))

    def opt_leaf(path, leaf):
        if strategy == "zero1":
            dims = leaf.shape[1:]
            spec = [None] * len(dims)
            for i, dsz in enumerate(dims):
                if dsz % flat_dp == 0 and dsz >= flat_dp:
                    axes = tuple(a for a in ("data", "model")
                                 if a in mesh.axis_names)
                    spec[i] = axes if len(axes) > 1 else axes[0]
                    break
            return NamedSharding(mesh, P(lead, *spec))
        return param_leaf(path, leaf)

    def spec_tree(tree, leaf_fn):
        flat = jax.tree_util.tree_flatten_with_path(tree)
        leaves = []
        for kp, leaf in flat[0]:
            path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                            for k in kp)
            leaves.append(leaf_fn(path, leaf))
        return jax.tree_util.tree_unflatten(flat[1], leaves)

    params_sh = spec_tree(state_shapes.params, param_leaf)
    opt_sh = type(state_shapes.opt)(
        NamedSharding(mesh, P(lead)),
        spec_tree(state_shapes.opt.m, opt_leaf),
        spec_tree(state_shapes.opt.v, opt_leaf))
    tp = sharding.axis_size(mesh, "model")
    shard_v = strategy != "dp_only" and cfg.vocab_size % tp == 0
    proto_spec = P("model", None) if shard_v else P(None, None)
    cnt_spec = P("model") if shard_v else P(None)
    proto_sh = prototypes.ProtoState(NamedSharding(mesh, proto_spec),
                                     NamedSharding(mesh, cnt_spec))
    return TrainState(params_sh, opt_sh, proto_sh,
                      NamedSharding(mesh, P()))


def round_sync_shardings(mesh, n_clients: int = 1):
    """Placement-resolved shardings for the per-round prototype exchange
    (`make_round_sync` / `make_async_round_sync` /
    `make_download_lag_round_sync`), via the SAME declarations the
    collaborative engines use (repro.relay.placement), resolved against
    this path's "pod" client axis:

      - the shared / pending / history ProtoStates are REPLICATED — the
        pending buffer here is delay-slot-indexed (not client-indexed,
        unlike relay/events.py) and the ring snapshots a replicated state,
        so there is nothing to shard;
      - per-client bucket stats (leading client axis) are CLIENT_SHARDED
        over "pod" — their sum inside round_sync is then the round's one
        CLIENT_SHARDED -> REPLICATED exchange, exactly like
        `placement.exchange` in core/vec_collab.py.

    Returns (replicated, stats) NamedShardings for jit in/out_shardings;
    on a single-client or pod-less mesh both are replicated (the identity
    placement)."""
    lead = _client_lead(mesh, n_clients)
    rep = placement.resolve(placement.REPLICATED, mesh)
    stats = (placement.resolve(placement.CLIENT_SHARDED, mesh, axis=lead)
             if lead else rep)
    return rep, stats


def batch_shardings(batch_shapes, mesh, n_clients: int = 1, *,
                    strategy: str = "tp"):
    lead = _client_lead(mesh, n_clients)
    baxes = ("data", "model") if strategy in ("dp_only", "zero1") else "data"

    def leaf(l):
        # (N, Bc, ...) -> client over pod, batch over data (+model: dp_only)
        rest = (None,) * (len(l.shape) - 2)
        return NamedSharding(mesh, P(lead, baxes, *rest))
    return jax.tree.map(leaf, batch_shapes)
