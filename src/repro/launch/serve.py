"""Serving steps: prefill (build KV caches + first logits) and decode (one
token against a seq_len cache) — what the decode_32k / long_500k dry-run
shapes lower. CoRS is a training-time technique; serving is the plain model,
so these steps carry no prototype traffic.

Cache sharding: batch over "data" when divisible; otherwise (long_500k,
B=1) the cache *sequence* axis is sharded over "data". KV heads shard over
"model" when divisible, else head_dim, else replicated (sharding.head_axis_plan).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import sharding
from repro.models import encdec, lm
from repro.types import ModelConfig, ShapeConfig


def decode_window(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """Effective attention cache length for this shape (sliding-window
    variant for long_500k on attention archs; DESIGN.md skip matrix)."""
    if shape.seq_len >= 1 << 19 and cfg.long_context_mode == "swa":
        return cfg.swa_window
    return 0


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------
def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        if cfg.is_encoder_decoder:
            enc = encdec.encode(params, cfg, batch["frames"])
            out = encdec.decode_forward(params, cfg, batch["tokens"], enc,
                                        mode="prefill")
        else:
            out = lm.forward(params, cfg, batch, mode="prefill")
        return {"logits": out["logits"][:, -1:, :], "caches": out["caches"]}
    return prefill_step


def make_decode_step(cfg: ModelConfig, *, window: int = 0):
    def decode_step(params, batch, caches):
        if cfg.is_encoder_decoder:
            out = encdec.decode_forward(
                params, cfg, batch["tokens"], None, mode="decode",
                self_cache=caches["self"], cross_kv=caches["cross"])
            return {"logits": out["logits"], "caches": out["caches"]}
        out = lm.decode_step(params, cfg, batch, caches, window=window)
        return {"logits": out["logits"], "caches": out["caches"]}
    return decode_step


# ---------------------------------------------------------------------------
# abstract inputs
# ---------------------------------------------------------------------------
def params_shapes(cfg: ModelConfig):
    def init():
        key = jax.random.PRNGKey(0)
        return (encdec.init_encdec(key, cfg) if cfg.is_encoder_decoder
                else lm.init_lm(key, cfg))
    return jax.eval_shape(init)


def serve_batch_specs(cfg: ModelConfig, shape: ShapeConfig):
    sds = jax.ShapeDtypeStruct
    B = shape.global_batch
    S = shape.seq_len if shape.mode == "prefill" else 1
    batch: Dict[str, Any] = {}
    if cfg.input_kind == "tokens" or cfg.is_encoder_decoder:
        batch["tokens"] = sds((B, S), jnp.int32)
    else:
        batch["embeddings"] = sds((B, S, cfg.d_model), jnp.dtype(cfg.dtype))
        if cfg.rope_kind == "mrope":
            batch["positions"] = sds((B, S, 3), jnp.int32)
    if cfg.is_encoder_decoder and shape.mode == "prefill":
        batch["frames"] = sds((B, cfg.encoder_seq, cfg.d_model),
                              jnp.dtype(cfg.dtype))
    return batch


def cache_shapes(cfg: ModelConfig, shape: ShapeConfig):
    window = decode_window(cfg, shape)
    if cfg.is_encoder_decoder:
        def init():
            self_c = encdec.init_self_cache(cfg, shape.global_batch,
                                            shape.seq_len)
            L = cfg.num_layers
            z = lambda hd: jnp.zeros((L, shape.global_batch, cfg.encoder_seq,
                                      cfg.num_kv_heads, hd),
                                     jnp.dtype(cfg.dtype))
            return {"self": self_c, "cross": (z(cfg.head_dim),
                                              z(cfg.v_head_dim))}
        return jax.eval_shape(init)
    return jax.eval_shape(
        lambda: lm.init_cache(cfg, shape.global_batch, shape.seq_len,
                              window=window))


# ---------------------------------------------------------------------------
# shardings
# ---------------------------------------------------------------------------
def params_shardings(pshapes, cfg: ModelConfig, mesh):
    flat = jax.tree_util.tree_flatten_with_path(pshapes)
    leaves = []
    for kp, leaf in flat[0]:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        # serving: no FSDP (weights stay resident); TP over model axis only
        spec = sharding.param_spec(path, leaf.shape, mesh, fsdp=False)
        leaves.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(flat[1], leaves)


def _cache_leaf_spec(shape, cfg: ModelConfig, mesh, batch: int,
                     shard_seq: bool):
    """Heuristic spec for a stacked cache leaf (leading layer axis)."""
    dp = sharding.dp_axes(mesh)
    tp = sharding.axis_size(mesh, "model")
    nd = len(shape)
    spec = [None] * nd
    # find the batch dim (first dim == batch after the layer axis)
    bdim = 1 if nd >= 2 and shape[1] == batch else None
    if bdim is not None and not shard_seq:
        if batch % sharding.dp_size(mesh) == 0:
            spec[bdim] = dp if len(dp) > 1 else dp[0]
    if shard_seq and nd >= 3:
        # long-context: shard the sequence axis (dim 2) over data
        if shape[2] % sharding.dp_size(mesh) == 0:
            spec[2] = dp if len(dp) > 1 else dp[0]
    # shard a trailing "heads-like" or feature dim over model
    for d in range(nd - 2, 1, -1):
        if spec[d] is None and shape[d] % tp == 0 and shape[d] >= tp:
            spec[d] = "model"
            break
    else:
        if nd >= 2 and spec[-1] is None and shape[-1] % tp == 0 \
                and shape[-1] >= tp:
            spec[-1] = "model"
    return P(*spec)


def cache_shardings(cshapes, cfg: ModelConfig, mesh, shape: ShapeConfig):
    shard_seq = shape.global_batch < sharding.dp_size(mesh)
    return jax.tree.map(
        lambda l: NamedSharding(
            mesh, _cache_leaf_spec(l.shape, cfg, mesh, shape.global_batch,
                                   shard_seq)),
        cshapes)


def batch_shardings(bshapes, mesh):
    def leaf(l):
        if l.shape[0] % sharding.dp_size(mesh) == 0:
            dp = sharding.dp_axes(mesh)
            lead = dp if len(dp) > 1 else dp[0]
            return NamedSharding(mesh, P(lead, *([None] * (len(l.shape) - 1))))
        return NamedSharding(mesh, P(*([None] * len(l.shape))))
    return jax.tree.map(leaf, bshapes)
