"""Roofline terms from a compiled dry-run artifact (no hardware needed).

  compute_s    = HLO_FLOPs_per_device / peak_FLOPs_chip     (197 TF/s bf16, v5e)
  memory_s     = HLO_bytes_per_device / HBM_bw              (819 GB/s)
  collective_s = collective_bytes_per_device / link_bw      (~50 GB/s ICI)

cost_analysis() reports the per-device (post-SPMD-partition) program, so no
further division by chip count is needed. collective_bytes is parsed from the
compiled HLO text: the summed operand sizes of every all-reduce / all-gather /
reduce-scatter / all-to-all / collective-permute (async *-start forms counted
once; *-done skipped).

Scan correction: XLA's cost analysis counts a while-loop body ONCE regardless
of trip count (verified empirically), and our models scan over layers. The
roofline therefore does NOT read the full compiled program's flops; instead
`estimate()` compiles 2-3 shallow *fully-unrolled* depth variants of the same
config (full width, same sharding) and solves the linear model
    cost = fixed + Σ_kind n_kind · per_layer_kind
for exact per-layer costs, then evaluates it at the real depth. The full
scanned compile remains the sharding/memory proof.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

import numpy as np

PEAK_FLOPS = 197e12        # bf16 per chip, TPU v5e
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * b


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """-> {op_kind: operand_bytes_total, ..., 'total': sum, 'count': n_ops}."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    count = 0
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.search(r"=\s*(?:\([^)]*\)|\S+)\s+([a-z0-9-]+)(?:-start)?\(",
                      ls)
        if not m:
            continue
        op = m.group(1)
        if op.endswith("-done"):
            continue
        kind = next((k for k in _COLLECTIVES
                     if op == k or op == k + "-start"), None)
        if kind is None:
            continue
        count += 1
        shapes = _SHAPE_RE.findall(ls)
        if not shapes:
            continue
        # first shape(s) before the op name are the result; operands follow
        # inside parens. Split at the op position.
        paren = ls.index(op + "(") + len(op) + 1 if op + "(" in ls \
            else ls.index("(")
        operand_txt = ls[paren:]
        op_shapes = _SHAPE_RE.findall(operand_txt)
        use = op_shapes if op_shapes else shapes[:1]
        out[kind] += sum(_shape_bytes(d, s) for d, s in use)
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["count"] = count
    return out


def terms(cost: Optional[dict], coll: Dict[str, int]) -> Dict[str, float]:
    flops = float((cost or {}).get("flops", 0.0))
    byts = float((cost or {}).get("bytes accessed", 0.0))
    t = {
        "flops": flops,
        "bytes": byts,
        "collective_bytes": float(coll.get("total", 0)),
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": byts / HBM_BW,
        "collective_s": float(coll.get("total", 0)) / LINK_BW,
    }
    dom = max(("compute_s", "memory_s", "collective_s"), key=lambda k: t[k])
    t["bottleneck"] = dom.replace("_s", "")
    return t


def model_flops(cfg, shape, n_clients: int = 1) -> float:
    """6·N_active·D per step (training: fwd+bwd; decode: 2·N·tokens)."""
    n = active_params(cfg)
    tokens = shape.global_batch * (shape.seq_len if shape.mode == "train"
                                   else 1)
    mult = 6.0 if shape.mode == "train" else 2.0
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mult = 2.0
    return mult * n * tokens


def active_params(cfg) -> float:
    """Parameter count with MoE counted at top-k active experts."""
    d, L, V = cfg.d_model, cfg.num_layers, cfg.vocab_size
    total = 2.0 * V * d  # embed + head
    for kind in cfg.block_pattern:
        if kind == "attn":
            total += _attn_params(cfg) + _ffn_active(cfg)
        elif kind == "mamba":
            di, N, H = cfg.d_inner, cfg.ssm_state, cfg.mamba_heads
            total += d * (2 * di + 2 * N + H) + di * d
        elif kind == "mlstm":
            di = 2 * d
            total += d * 2 * di + 3 * di * di + di * d
        elif kind == "slstm":
            total += 4 * d * d + 4 * d * (d // cfg.num_heads) + 3 * d * d
    if cfg.shared_attn_period:
        total += _attn_params(cfg) + _ffn_active(cfg)
    if cfg.is_encoder_decoder:
        total += cfg.num_encoder_layers * (
            4 * d * cfg.num_heads * cfg.head_dim + 2 * d * cfg.d_ff)
        total += cfg.num_layers * (4 * d * cfg.num_heads * cfg.head_dim)
    return total


def _attn_params(cfg) -> float:
    d = cfg.d_model
    if cfg.is_mla:
        r_kv, dn, dr, dv = (cfg.kv_lora_rank, cfg.qk_nope_dim,
                            cfg.qk_rope_dim, cfg.v_head_dim)
        H = cfg.num_heads
        q_in = cfg.q_lora_rank or d
        q = (d * cfg.q_lora_rank if cfg.q_lora_rank else 0) \
            + q_in * H * (dn + dr)
        kv = d * (r_kv + dr) + r_kv * H * (dn + dv)
        return q + kv + H * dv * d
    H, G, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return d * H * hd + 2 * d * G * hd + H * hd * d


def _ffn_active(cfg) -> float:
    d = cfg.d_model
    if cfg.num_experts:
        k = cfg.experts_per_token + cfg.num_shared_experts
        return 3.0 * d * cfg.moe_d_ff * k + d * cfg.num_experts
    return 3.0 * d * cfg.d_ff if cfg.mlp_kind == "swiglu" else 2.0 * d * cfg.d_ff


# ---------------------------------------------------------------------------
# scan-corrected estimation via shallow unrolled depth variants
# ---------------------------------------------------------------------------
def depth_variants(cfg) -> Tuple[List, List[Dict[str, float]], List[str]]:
    """Returns (configs, count-dicts, unknown-names). Each config is a
    shallow full-width variant; counts give the per-kind layer multiplicity
    (plus the implicit fixed term)."""
    kinds = sorted(set(cfg.block_pattern))
    mk = lambda **kw: dataclasses.replace(cfg, **kw)
    if cfg.is_encoder_decoder:
        names = ["enc", "dec"]
        att = lambda n: ("attn",) * n
        cfgs = [mk(num_encoder_layers=1, num_layers=1, block_pattern=att(1)),
                mk(num_encoder_layers=2, num_layers=1, block_pattern=att(1)),
                mk(num_encoder_layers=1, num_layers=2, block_pattern=att(2))]
        counts = [{"enc": 1, "dec": 1}, {"enc": 2, "dec": 1},
                  {"enc": 1, "dec": 2}]
        return cfgs, counts, names
    if cfg.shared_attn_period:
        # zamba2: unknowns = mamba layer, shared-attn application
        names = ["mamba", "shared"]
        cfgs = [mk(num_layers=2, block_pattern=("mamba",) * 2,
                   shared_attn_period=2),            # 2 mamba + 1 shared
                mk(num_layers=3, block_pattern=("mamba",) * 3,
                   shared_attn_period=3),            # 3 mamba + 1 shared
                mk(num_layers=2, block_pattern=("mamba",) * 2,
                   shared_attn_period=1)]            # 2 mamba + 2 shared
        counts = [{"mamba": 2, "shared": 1}, {"mamba": 3, "shared": 1},
                  {"mamba": 2, "shared": 2}]
        return cfgs, counts, names
    if len(kinds) == 1:
        k = kinds[0]
        cfgs = [mk(num_layers=1, block_pattern=(k,)),
                mk(num_layers=2, block_pattern=(k, k))]
        counts = [{k: 1}, {k: 2}]
        return cfgs, counts, [k]
    # mixed pattern (xlstm): one variant per extra kind + base
    names = kinds
    base = tuple(kinds)
    cfgs = [mk(num_layers=len(base), block_pattern=base)]
    counts = [{k: 1 for k in kinds}]
    for k in kinds:
        pat = base + (k,)
        cfgs.append(mk(num_layers=len(pat), block_pattern=pat))
        c = {kk: 1 for kk in kinds}
        c[k] += 1
        counts.append(c)
    return cfgs, counts, names


def real_counts(cfg) -> Dict[str, float]:
    if cfg.is_encoder_decoder:
        return {"enc": cfg.num_encoder_layers, "dec": cfg.num_layers}
    c: Dict[str, float] = {}
    for k in cfg.block_pattern:
        c[k] = c.get(k, 0) + 1
    if cfg.shared_attn_period:
        c["shared"] = len([i for i in range(cfg.shared_attn_period,
                                            cfg.num_layers + 1,
                                            cfg.shared_attn_period)])
    return c


def solve_linear(counts: List[Dict[str, float]], names: List[str],
                 values: List[float]) -> Dict[str, float]:
    """Least-squares solve values_i = fixed + Σ counts_i[k]·coef[k]."""
    A = np.array([[1.0] + [c.get(k, 0.0) for k in names] for c in counts])
    b = np.array(values, dtype=np.float64)
    coef, *_ = np.linalg.lstsq(A, b, rcond=None)
    out = {"fixed": float(coef[0])}
    for k, v in zip(names, coef[1:]):
        out[k] = float(v)
    return out


def evaluate_linear(coefs: Dict[str, float], counts: Dict[str, float]) -> float:
    tot = coefs.get("fixed", 0.0)
    for k, n in counts.items():
        tot += coefs.get(k, 0.0) * n
    return max(0.0, tot)


# ---------------------------------------------------------------------------
# analytic per-device memory floor (sanity bound next to the compiled
# memory_analysis, which on the CPU backend overestimates: no TPU buffer
# sharing, f32 upcasts of bf16 matmuls, no fusion)
# ---------------------------------------------------------------------------
def memory_floor_bytes(cfg, shape, n_devices: int, *, n_clients: int = 1,
                       dtype_bytes: int = 2) -> Dict[str, float]:
    P_count = active_params_total(cfg)
    out: Dict[str, float] = {}
    if shape.mode == "train":
        # params bf16 + grads bf16 + Adam m,v f32 (all sharded) per client
        per_client = P_count * (dtype_bytes * 2 + 8)
        out["states"] = n_clients * per_client / n_devices
        # one activation checkpoint per layer boundary
        tokens = shape.global_batch * shape.seq_len
        out["activations"] = (tokens * cfg.d_model * dtype_bytes
                              * cfg.num_layers) / n_devices
        out["logits"] = tokens * cfg.vocab_size * dtype_bytes / n_devices
        out["proto"] = cfg.vocab_size * (cfg.d_feature + 1) * 4 / n_devices
    else:
        out["params"] = P_count * dtype_bytes / n_devices
        if shape.mode == "decode":
            out["cache"] = _cache_bytes(cfg, shape, dtype_bytes) / n_devices
        else:
            tokens = shape.global_batch * shape.seq_len
            out["activations"] = (tokens * cfg.d_model * dtype_bytes * 2
                                  ) / n_devices
            out["cache"] = _cache_bytes(cfg, shape, dtype_bytes) / n_devices
    out["total"] = sum(out.values())
    return out


def active_params_total(cfg) -> float:
    """Total resident parameters (MoE counts ALL experts, not just top-k)."""
    n = active_params(cfg)
    if cfg.num_experts:
        d = cfg.d_model
        per_layer_extra = 3.0 * d * cfg.moe_d_ff * (
            cfg.num_experts - cfg.experts_per_token)
        n += per_layer_extra * sum(1 for k in cfg.block_pattern if k == "attn")
    return n


def _cache_bytes(cfg, shape, dtype_bytes: int) -> float:
    B = shape.global_batch
    S = shape.seq_len
    if getattr(cfg, "long_context_mode", "") == "swa" and S >= 1 << 19:
        S = cfg.swa_window
    total = 0.0
    for kind in cfg.block_pattern:
        if kind == "attn":
            if cfg.is_mla:
                total += B * S * (cfg.kv_lora_rank + cfg.qk_rope_dim)
            else:
                total += 2 * B * S * cfg.num_kv_heads * cfg.head_dim
        elif kind == "mamba":
            total += B * cfg.mamba_heads * cfg.mamba_head_dim * cfg.ssm_state * 2
        elif kind in ("mlstm", "slstm"):
            total += B * cfg.d_model * 8
    if cfg.shared_attn_period:
        n_sh = len(range(cfg.shared_attn_period, cfg.num_layers + 1,
                         cfg.shared_attn_period))
        total += n_sh * 2 * B * shape.seq_len * cfg.num_kv_heads * cfg.head_dim
    if cfg.is_encoder_decoder:
        total += 2 * B * cfg.encoder_seq * cfg.num_kv_heads * cfg.head_dim \
            * cfg.num_layers
    return total * dtype_bytes
