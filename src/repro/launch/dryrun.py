import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) combination
on 512 placeholder host devices — proves the sharding config is coherent and
yields the roofline inputs (memory_analysis / cost_analysis / HLO collectives).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \\
      --shape train_4k [--multi-pod] [--mode cors|fedavg|il]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Artifacts: artifacts/dryrun/<arch>__<shape>__<mesh>__<mode>.json
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCHS, SHAPES, get_arch, get_shape
from repro.launch import roofline, serve as serve_lib, train as train_lib
from repro.launch.mesh import make_production_mesh
from repro.types import CollabConfig

ARTDIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                      "artifacts", "dryrun")


def should_skip(cfg, shape) -> str:
    if shape.name == "long_500k" and cfg.long_context_mode == "skip":
        return ("enc-dec with a 30s audio frontend has no 500k-token decode "
                "regime (DESIGN.md shape/skip matrix)")
    return ""


def build_lowered(cfg, shape, mesh, *, mode: str, n_clients: int,
                  strategy: str = "tp", moe_ep: bool = False,
                  sync: str = "step"):
    """Lower the step for (cfg, shape) on `mesh` with full shardings.

    §Perf knobs: strategy ("tp" | "dp_only"), moe_ep (expert-parallel
    sharding), sync ("step" = exchange folded into every step;
    "round" = paper Algorithm 1 cadence, exchange amortized per round)."""
    from repro import sharding as sharding_mod
    sharding_mod.set_hints(mesh=mesh, moe_ep=moe_ep,
                           moe_dp=strategy in ("dp_only", "zero1"))
    if shape.mode == "train":
        ccfg = CollabConfig(mode=mode, num_classes=cfg.vocab_size,
                            d_feature=cfg.d_feature, num_negatives=1023)
        step = train_lib.make_train_step(cfg, ccfg, n_clients=n_clients,
                                         sync_in_step=(sync == "step"))
        state = train_lib.init_state_shapes(cfg, n_clients)
        batch = train_lib.train_batch_specs(cfg, shape, n_clients)
        state_sh = train_lib.state_shardings(state, cfg, mesh, n_clients,
                                             strategy=strategy)
        batch_sh = train_lib.batch_shardings(batch, mesh, n_clients,
                                             strategy=strategy)
        seed = jax.ShapeDtypeStruct((), jax.numpy.int32)
        fn = jax.jit(lambda st, b, s: step(st, b, jax.random.PRNGKey(s)),
                     in_shardings=(state_sh, batch_sh, None))
        return fn.lower(state, batch, seed)
    if shape.mode == "prefill":
        step = serve_lib.make_prefill_step(cfg)
        params = serve_lib.params_shapes(cfg)
        batch = serve_lib.serve_batch_specs(cfg, shape)
        p_sh = serve_lib.params_shardings(params, cfg, mesh)
        b_sh = serve_lib.batch_shardings(batch, mesh)
        return jax.jit(step, in_shardings=(p_sh, b_sh)).lower(params, batch)
    window = serve_lib.decode_window(cfg, shape)
    step = serve_lib.make_decode_step(cfg, window=window)
    params = serve_lib.params_shapes(cfg)
    batch = serve_lib.serve_batch_specs(cfg, shape)
    caches = serve_lib.cache_shapes(cfg, shape)
    p_sh = serve_lib.params_shardings(params, cfg, mesh)
    b_sh = serve_lib.batch_shardings(batch, mesh)
    c_sh = serve_lib.cache_shardings(caches, cfg, mesh, shape)
    return jax.jit(step, in_shardings=(p_sh, b_sh, c_sh)).lower(
        params, batch, caches)


def _compile_metrics(compiled):
    cost = dict(compiled.cost_analysis() or {})
    hlo = compiled.as_text()
    coll = roofline.collective_bytes(hlo)
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": float(coll["total"]), "coll_detail": coll,
            "hlo_bytes": len(hlo)}


def estimate_corrected(cfg, shape, mesh, *, mode: str, n_clients: int,
                       **knobs):
    """Scan-corrected roofline inputs via shallow unrolled depth variants
    (see roofline.py module docstring)."""
    from repro.models import blocks
    cfgs, counts, names = roofline.depth_variants(cfg)
    vals = {"flops": [], "bytes": [], "coll": []}
    blocks.UNROLL = True
    try:
        for vc in cfgs:
            lowered = build_lowered(vc, shape, mesh, mode=mode,
                                    n_clients=n_clients, **knobs)
            m = _compile_metrics(lowered.compile())
            for k in vals:
                vals[k].append(m[k])
    finally:
        blocks.UNROLL = False
    rc = roofline.real_counts(cfg)
    corrected = {}
    probes = {}
    for k, v in vals.items():
        coefs = roofline.solve_linear(counts, names, v)
        corrected[k] = roofline.evaluate_linear(coefs, rc)
        probes[k] = {"coefs": coefs, "probe_values": v}
    return corrected, probes


def lower_one(arch: str, shape_name: str, *, multi_pod: bool,
              mode: str = "cors", with_roofline: bool = True,
              strategy: str = "tp", moe_ep: bool = False,
              sync: str = "step", tag: str = ""):
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    skip = should_skip(cfg, shape)
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag, "mode": mode,
           "status": "skip", "skip_reason": skip, "tag": tag,
           "knobs": {"strategy": strategy, "moe_ep": moe_ep, "sync": sync}}
    if skip:
        return rec

    knobs = dict(strategy=strategy, moe_ep=moe_ep, sync=sync)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_clients = mesh.shape.get("pod", 1)
    with mesh:
        t0 = time.time()
        lowered = build_lowered(cfg, shape, mesh, mode=mode,
                                n_clients=n_clients, **knobs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        try:
            mem = compiled.memory_analysis()
            mem_rec = {k: int(getattr(mem, k)) for k in
                       ("argument_size_in_bytes", "output_size_in_bytes",
                        "temp_size_in_bytes", "generated_code_size_in_bytes")
                       if hasattr(mem, k)}
        except Exception as e:  # pragma: no cover - backend specific
            mem_rec = {"error": str(e)}
        raw = _compile_metrics(compiled)

        corrected, probes = (raw, None)
        if with_roofline:
            corrected, probes = estimate_corrected(
                cfg, shape, mesh, mode=mode, n_clients=n_clients, **knobs)

    terms = roofline.terms({"flops": corrected["flops"],
                            "bytes accessed": corrected["bytes"]},
                           {"total": corrected["coll"]})
    mf = roofline.model_flops(cfg, shape, n_clients)
    n_dev = mesh.size
    hlo_flops_global = terms["flops"] * n_dev
    rec.update(
        status="ok", n_devices=n_dev,
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        raw_scan_metrics=raw, probes=probes,
        memory=mem_rec, terms=terms,
        model_flops_global=mf,
        useful_flops_ratio=(mf / hlo_flops_global
                            if hlo_flops_global else None))
    return rec


def save(rec, outdir=ARTDIR):
    os.makedirs(outdir, exist_ok=True)
    tag = rec.get("tag", "") or ""
    if tag:
        tag = "__" + tag
    name = (f"{rec['arch']}__{rec['shape']}__{rec['mesh']}__{rec['mode']}"
            f"{tag}.json")
    with open(os.path.join(outdir, name), "w") as f:
        json.dump(rec, f, indent=1, default=str)
    return name


def fmt(rec) -> str:
    if rec["status"] != "ok":
        return (f"{rec['arch']:24s} {rec['shape']:12s} {rec['mesh']:12s} "
                f"{rec['status'].upper()}: {rec.get('skip_reason', rec.get('error', ''))[:60]}")
    t = rec["terms"]
    return (f"{rec['arch']:24s} {rec['shape']:12s} {rec['mesh']:12s} "
            f"compile={rec['compile_s']:6.1f}s "
            f"comp={t['compute_s']*1e3:8.2f}ms mem={t['memory_s']*1e3:8.2f}ms "
            f"coll={t['collective_s']*1e3:8.2f}ms -> {t['bottleneck']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mode", default="cors",
                    choices=["cors", "fedavg", "il"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-roofline", action="store_true",
                    help="compile proof only (multi-pod pass)")
    ap.add_argument("--strategy", default="tp",
                    choices=["tp", "dp_only", "zero1"])
    ap.add_argument("--moe-ep", action="store_true",
                    help="expert-parallel MoE sharding (§Perf variant)")
    ap.add_argument("--sync", default="step", choices=["step", "round"],
                    help="prototype exchange cadence (§Perf variant)")
    ap.add_argument("--remat", default="full",
                    choices=["full", "dots", "none"],
                    help="activation checkpoint policy (§Perf variant)")
    ap.add_argument("--tag", default="", help="artifact filename suffix")
    ap.add_argument("--out", default=ARTDIR)
    args = ap.parse_args()

    combos = []
    archs = [args.arch] if args.arch else sorted(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    for a in archs:
        for s in shapes:
            combos.append((a, s))
    from repro.models import blocks as _blocks
    _blocks.REMAT_POLICY = args.remat
    ok = skip = fail = 0
    for a, s in combos:
        try:
            rec = lower_one(a, s, multi_pod=args.multi_pod, mode=args.mode,
                            with_roofline=not args.no_roofline,
                            strategy=args.strategy, moe_ep=args.moe_ep,
                            sync=args.sync, tag=args.tag)
        except Exception as e:
            rec = {"arch": a, "shape": s,
                   "mesh": "pod2x16x16" if args.multi_pod else "pod16x16",
                   "mode": args.mode, "status": "fail",
                   "error": f"{type(e).__name__}: {e}",
                   "trace": traceback.format_exc()[-2000:]}
        save(rec, args.out)
        print(fmt(rec), flush=True)
        ok += rec["status"] == "ok"
        skip += rec["status"] == "skip"
        fail += rec["status"] == "fail"
    print(f"\n== dry-run summary: {ok} ok / {skip} skip / {fail} fail ==")
    if fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
