"""Shared benchmark harness utilities."""
from __future__ import annotations

import os
import time
import jax

from repro.core import client as client_lib, collab, vec_collab
from repro.data import partition, synthetic
from repro.models import cnn, mlp
from repro.types import CollabConfig, FleetConfig, TrainConfig

ROUNDS = int(os.environ.get("REPRO_BENCH_ROUNDS", "12"))
N_TRAIN = int(os.environ.get("REPRO_BENCH_TRAIN", "1200"))
N_TEST = int(os.environ.get("REPRO_BENCH_TEST", "2000"))
NOISE = float(os.environ.get("REPRO_BENCH_NOISE", "0.8"))

SPEC = client_lib.ClientSpec(
    apply=lambda p, x: cnn.apply(p, x),
    head=lambda p: (p["head_w"], p["head_b"]))

MLP_SPEC = client_lib.ClientSpec(
    apply=lambda p, x: mlp.apply(p, x),
    head=lambda p: (p["head_w"], p["head_b"]))


def data(seed=0):
    x, y = synthetic.class_images(N_TRAIN, seed=seed, noise=NOISE)
    tx, ty = synthetic.class_images(N_TEST, seed=seed + 99, noise=NOISE)
    return (x, y), (tx, ty)


def hetero_fleet(mix: str, n_clients: int, seed: int = 0):
    """Build a mixed-architecture fleet from a mix spec like
    "mlp:64,mlp:128" or "mlp:64,cnn:1" — entries are model:size
    (mlp hidden width / cnn width multiplier), assigned round-robin so
    buckets interleave across client ids (the hard case for the bucketed
    engine's ordering). ONE ClientSpec object per entry, shared by all of
    that entry's clients, so `client_lib.bucketize` stacks them."""
    entries = []
    for item in mix.split(","):
        model, _, size = item.strip().partition(":")
        size = int(size) if size else (64 if model == "mlp" else 1)
        if model == "mlp":
            spec = client_lib.ClientSpec(
                apply=lambda p, x: mlp.apply(p, x),
                head=lambda p: (p["head_w"], p["head_b"]))
            init = lambda k, h=size: mlp.init_mlp(k, hidden=h)
        elif model == "cnn":
            spec = client_lib.ClientSpec(
                apply=lambda p, x: cnn.apply(p, x),
                head=lambda p: (p["head_w"], p["head_b"]))
            init = lambda k, w=size: cnn.init_cnn(k, width=w)
        else:
            raise ValueError(f"unknown hetero mix entry: {item!r}")
        entries.append((spec, init))
    keys = jax.random.split(jax.random.PRNGKey(seed), n_clients)
    specs = [entries[i % len(entries)][0] for i in range(n_clients)]
    params = [entries[i % len(entries)][1](k) for i, k in enumerate(keys)]
    return specs, params


def make_trainer(mode: str, n_clients: int, *, lambda_kd: float = 10.0,
                 lambda_disc: float = 1.0, seed: int = 0, width: int = 1,
                 engine: str = "vec", batch_size: int = 32,
                 train_data=None, test_data=None, model: str = "cnn",
                 policy=None, participation=None, hetero: str = None,
                 clock=None, download_clock=None, mesh=None, arrivals=None,
                 fleet=None, telemetry=None):
    """Build a trainer without running it. engine: "vec" (default — ALL
    benchmark fleets go through the vectorized engine, homogeneous ones as
    one fused round step and mixed ones bucketed; there is no seq
    special-case for heterogeneous specs) or "seq" (the per-client
    Python-loop oracle, any mix). model: "cnn" (paper's LeNet) or "mlp"
    (cheap-compute client, see models/mlp.py). hetero: a `hetero_fleet`
    mix spec (e.g. "mlp:64,mlp:128") that overrides `model`/`width` with a
    mixed-architecture fleet. policy / participation: relay-policy and
    participation-schedule specs forwarded to the trainer (see
    repro.relay.get_policy / get_schedule), e.g. policy="per_class",
    participation="uniform_k:8". clock: a repro.sim ClockModel spec (e.g.
    "lognormal:4") driving the asynchronous event-ordered relay.
    download_clock: a repro.sim download-lag spec (e.g. "lognormal:4") —
    clients read stale relay snapshots from the bounded history ring
    (repro.relay.history). mesh: a jax Mesh with a "clients" axis — the
    placement-aware device path (repro.relay.placement). arrivals: a
    streaming-population spec (repro.sim.get_arrivals, e.g.
    "stream:3,2.0,0.2,100000,0") — clients join/leave an unbounded id
    space over `n_clients` SEATS, and participation is owned by the
    cohort table. fleet: pass a
    ready-made `repro.types.FleetConfig` instead of the loose
    policy/participation/clock/download_clock/mesh kwargs (mixing both is
    an error, mirroring `resolve_fleet`). telemetry: forwarded to the
    trainer (True or a repro.obs.TelemetryConfig; None = off — the
    benchmark default, so timings measure the telemetry-free program; the
    `telemetry` CI gate measures the on/off delta explicitly)."""
    if train_data is None or test_data is None:
        (x, y), test = data(seed)
    else:
        (x, y), test = train_data, test_data
    if mode == "cl":
        parts = [(x, y)]
        n_clients = 1
        mode_eff = "il"
    else:
        parts = partition.uniform_split(x, y, n_clients, seed=seed + 1)
        mode_eff = mode
    ccfg = CollabConfig(mode=mode_eff, num_classes=10, d_feature=84,
                        lambda_kd=lambda_kd if mode_eff in ("cors", "fd")
                        else 0.0,
                        lambda_disc=lambda_disc if mode_eff == "cors" else 0.0)
    tcfg = TrainConfig(batch_size=batch_size)
    keys = jax.random.split(jax.random.PRNGKey(seed), n_clients)
    if hetero is not None:
        specs, params = hetero_fleet(hetero, n_clients, seed=seed)
    elif model == "mlp":
        specs = [MLP_SPEC] * n_clients
        params = [mlp.init_mlp(k, hidden=64 * width) for k in keys]
    else:
        specs = [SPEC] * n_clients
        params = [cnn.init_cnn(k, width=width) for k in keys]
    cls = (vec_collab.VectorizedCollabTrainer if engine == "vec"
           else collab.CollabTrainer)
    loose = {"policy": policy, "participation": participation,
             "clock": clock, "download_clock": download_clock, "mesh": mesh,
             "arrivals": arrivals}
    loose = {k: v for k, v in loose.items() if v is not None}
    if fleet is None:
        fleet = FleetConfig(**loose)
    elif loose:
        raise ValueError(
            f"pass fleet=FleetConfig(...) OR loose kwargs, not both; got "
            f"fleet and {sorted(loose)}")
    return cls(specs, params, parts, test, ccfg, tcfg, seed=seed,
               fleet=fleet, telemetry=telemetry)


def run_mode(mode: str, n_clients: int, rounds: int = None, *,
             lambda_kd: float = 10.0, lambda_disc: float = 1.0,
             seed: int = 0, width: int = 1, engine: str = "vec"):
    rounds = rounds or ROUNDS
    tr = make_trainer(mode, n_clients, lambda_kd=lambda_kd,
                      lambda_disc=lambda_disc, seed=seed, width=width,
                      engine=engine)
    tr.run(rounds)
    return tr


def timeit(fn, *args, iters=10, warmup=2) -> float:
    """-> microseconds per call (post-jit, blocked)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6
