"""Shared benchmark harness utilities."""
from __future__ import annotations

import os
import time
from typing import Dict, List

import jax
import numpy as np

from repro.core import client as client_lib, collab
from repro.data import partition, synthetic
from repro.models import cnn
from repro.types import CollabConfig, TrainConfig

ROUNDS = int(os.environ.get("REPRO_BENCH_ROUNDS", "12"))
N_TRAIN = int(os.environ.get("REPRO_BENCH_TRAIN", "1200"))
N_TEST = int(os.environ.get("REPRO_BENCH_TEST", "2000"))
NOISE = float(os.environ.get("REPRO_BENCH_NOISE", "0.8"))

SPEC = client_lib.ClientSpec(
    apply=lambda p, x: cnn.apply(p, x),
    head=lambda p: (p["head_w"], p["head_b"]))


def data(seed=0):
    x, y = synthetic.class_images(N_TRAIN, seed=seed, noise=NOISE)
    tx, ty = synthetic.class_images(N_TEST, seed=seed + 99, noise=NOISE)
    return (x, y), (tx, ty)


def run_mode(mode: str, n_clients: int, rounds: int = None, *,
             lambda_kd: float = 10.0, lambda_disc: float = 1.0,
             seed: int = 0, width: int = 1) -> collab.CollabTrainer:
    rounds = rounds or ROUNDS
    (x, y), test = data(seed)
    if mode == "cl":
        parts = [(x, y)]
        n_clients = 1
        mode_eff = "il"
    else:
        parts = partition.uniform_split(x, y, n_clients, seed=seed + 1)
        mode_eff = mode
    ccfg = CollabConfig(mode=mode_eff, num_classes=10, d_feature=84,
                        lambda_kd=lambda_kd if mode_eff in ("cors", "fd")
                        else 0.0,
                        lambda_disc=lambda_disc if mode_eff == "cors" else 0.0)
    tcfg = TrainConfig(batch_size=32)
    params = [cnn.init_cnn(k, width=width) for k in
              jax.random.split(jax.random.PRNGKey(seed), n_clients)]
    tr = collab.CollabTrainer([SPEC] * n_clients, params, parts, test,
                              ccfg, tcfg, seed=seed)
    tr.run(rounds)
    return tr


def timeit(fn, *args, iters=10, warmup=2) -> float:
    """-> microseconds per call (post-jit, blocked)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6
