"""Roofline table (deliverable g): read artifacts/dryrun/*.json and print the
per-(arch × shape) three-term roofline, dominant bottleneck, MODEL_FLOPS
ratio. Single-pod mesh rows only (the multi-pod pass is a compile proof)."""
from __future__ import annotations

import glob
import json
import os

ARTDIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load(mesh="pod16x16", include_tagged=False):
    rows = []
    for f in sorted(glob.glob(os.path.join(ARTDIR, f"*__{mesh}__*.json"))):
        base = os.path.basename(f)[:-5]
        if not include_tagged and len(base.split("__")) != 4:
            continue                          # skip §Perf variant artifacts
        rec = json.load(open(f))
        rows.append(rec)
    return rows


def main():
    rows = load()
    print("arch,shape,compute_ms,memory_ms,collective_ms,bottleneck,"
          "useful_flops_ratio,status")
    for r in rows:
        if r["status"] != "ok":
            print(f"{r['arch']},{r['shape']},,,,,,{r['status']}")
            continue
        t = r["terms"]
        u = r.get("useful_flops_ratio")
        print(f"{r['arch']},{r['shape']},{t['compute_s']*1e3:.3f},"
              f"{t['memory_s']*1e3:.3f},{t['collective_s']*1e3:.3f},"
              f"{t['bottleneck']},{u if u is None else round(u, 3)},ok")
    if not rows:
        print("(no dry-run artifacts found — run "
              "`python -m repro.launch.dryrun --all` first)")
    return rows


if __name__ == "__main__":
    main()


def markdown(mesh="pod16x16"):
    """Render the §Roofline markdown table from artifacts."""
    rows = load(mesh)
    out = ["| arch | shape | compute | memory | collective | bottleneck | "
           "useful FLOPs | what would move the dominant term |",
           "|---|---|---|---|---|---|---|---|"]
    hints = {
        ("memory", "train"): "less remat recompute (--remat dots) / fused bf16",
        ("memory", "decode"): "weights+cache are read once: batch more queries per weight load",
        ("memory", "prefill"): "flash-attention fusion (Pallas kernel on TPU)",
        ("collective", "train"): "reshard: dp_only for small models, EP for MoE, round-sync protos",
        ("collective", "prefill"): "sequence-parallel reduce-scatter instead of TP all-reduce",
        ("collective", "decode"): "replicate small tensors; avoid resharding in scan body",
        ("compute", "train"): "already MXU-bound: larger per-device batch only",
    }
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"{r['status']} | — | {r.get('skip_reason','')[:40]} |")
            continue
        t = r["terms"]
        shape_kind = ("train" if "train" in r["shape"] else
                      "prefill" if "prefill" in r["shape"] else "decode")
        hint = hints.get((t["bottleneck"], shape_kind), "")
        u = r.get("useful_flops_ratio")
        out.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']*1e3:.2f} ms | "
            f"{t['memory_s']*1e3:.2f} ms | {t['collective_s']*1e3:.2f} ms | "
            f"**{t['bottleneck']}** | {u:.2f} | {hint} |")
    return "\n".join(out)


def markdown_dryrun(mesh="pod2x16x16"):
    rows = load(mesh)
    out = ["| arch | shape | status | compile s | HLO coll ops | "
           "per-device arg+temp GB |", "|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['status']} | — | — | — |")
            continue
        m = r.get("memory", {})
        gb = (m.get("argument_size_in_bytes", 0)
              + m.get("temp_size_in_bytes", 0)) / 1e9
        nc = r.get("raw_scan_metrics", {}).get("coll_detail", {}).get("count", "-")
        out.append(f"| {r['arch']} | {r['shape']} | ok | {r['compile_s']} | "
                   f"{nc} | {gb:.2f} |")
    return "\n".join(out)
