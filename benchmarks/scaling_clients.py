"""Client-scaling benchmark: round wall-clock vs N, sequential vs vectorized.

The paper's scalability claim is that CoRS cost does not blow up with the
number of users; the sequential simulation harness did (one Python dispatch
chain — relay, jitted update, EAGER upload computation — per client per
round). This measures the post-compile wall-clock of a full round (relay,
local updates, uploads, merge, eval) for both engines, weak-scaling: fixed
samples per client, so total work grows with N and a perfectly-scaling
engine has flat per-client cost.

Model choice matters for what you measure:
  - "mlp" (default): cheap per-client compute, so the number isolates the
    ENGINE overhead the vectorized path removes — this is where the
    >= 3x @ 32-clients acceptance bar applies.
  - "cnn": the paper's LeNet. On a few-core CPU its conv FLOPs saturate the
    machine under either engine, so the ratio measures compute batching
    (~1.1-1.6x here), not dispatch; on accelerators the batched path wins.

  PYTHONPATH=src python -m benchmarks.scaling_clients \
      [--clients 2,8,32,128] [--model mlp|cnn] [--rounds 3] \
      [--participation-sweep] [--participation-n 32]

CSV to stdout: model,n_clients,engine,s_per_round,speedup_vs_seq.

--participation-sweep instead measures partial client rounds (the
relay/participation subsystem): at fixed N, k/N ∈ {0.25, 0.5, 1.0} clients
per round via the uniform_k schedule. The vectorized engine compacts the
round step to the k participants, so both wall-clock AND comm volume per
round should fall ≈ linearly with k/N.
CSV: model,n_clients,k,s_per_round,comm_mb_per_round,speedup_vs_full.
"""
from __future__ import annotations

import argparse
import os
import time

from benchmarks import common
from repro.data import synthetic

PER_CLIENT = int(os.environ.get("REPRO_SCALE_PER_CLIENT", "64"))
N_TEST = int(os.environ.get("REPRO_SCALE_TEST", "1024"))
SEQ_MAX = int(os.environ.get("REPRO_SCALE_SEQ_MAX", "64"))


def time_rounds(trainer, rounds: int = 3) -> float:
    """Seconds per round, excluding the first (compile) round."""
    trainer.run_round()
    t0 = time.perf_counter()
    for _ in range(rounds):
        trainer.run_round()
    return (time.perf_counter() - t0) / rounds


def bench(n_clients: int, engine: str, model: str, rounds: int) -> float:
    train = synthetic.class_images(PER_CLIENT * n_clients, seed=0, noise=0.8)
    test = synthetic.class_images(N_TEST, seed=99, noise=0.8)
    tr = common.make_trainer("cors", n_clients, engine=engine, model=model,
                             batch_size=16, train_data=train, test_data=test)
    return time_rounds(tr, rounds)


def participation_sweep(n_clients: int = 32, rounds: int = 3,
                        model: str = "mlp", fractions=(0.25, 0.5, 1.0)):
    """Partial-round savings: s/round and comm/round vs participants k."""
    train = synthetic.class_images(PER_CLIENT * n_clients, seed=0, noise=0.8)
    test = synthetic.class_images(N_TEST, seed=99, noise=0.8)
    print("model,n_clients,k,s_per_round,comm_mb_per_round,speedup_vs_full")
    results = {}
    t_full = None
    for frac in sorted(fractions, reverse=True):     # full first (baseline)
        k = max(1, int(round(frac * n_clients)))
        tr = common.make_trainer(
            "cors", n_clients, engine="vec", model=model, batch_size=16,
            train_data=train, test_data=test,
            participation=f"uniform_k:{k}")
        t = time_rounds(tr, rounds)
        up, down = tr.ledger.by_round[-1]
        comm_mb = 4 * (up + down) / 1e6
        if t_full is None:
            t_full = t
        results[k] = (t, comm_mb, t_full / t)
        print(f"{model},{n_clients},{k},{t:.4f},{comm_mb:.4f},"
              f"{t_full / t:.2f}")
    return results


def main(clients=(2, 8, 32, 128), rounds: int = 3, model: str = "mlp"):
    print("model,n_clients,engine,s_per_round,speedup_vs_seq")
    results = {}
    for n in clients:
        t_vec = bench(n, "vec", model, rounds)
        if n <= SEQ_MAX:
            t_seq = bench(n, "seq", model, rounds)
            results[n] = t_seq / t_vec
            print(f"{model},{n},seq,{t_seq:.4f},1.00")
            print(f"{model},{n},vec,{t_vec:.4f},{results[n]:.2f}")
        else:
            results[n] = None
            print(f"{model},{n},seq,skipped,")
            print(f"{model},{n},vec,{t_vec:.4f},")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", default="2,8,32,128")
    ap.add_argument("--model", default="mlp", choices=["mlp", "cnn"])
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--participation-sweep", action="store_true",
                    help="measure partial rounds (k/N in {0.25,0.5,1.0}) "
                         "instead of the seq-vs-vec engine scaling")
    ap.add_argument("--participation-n", type=int, default=32,
                    help="N for the participation sweep")
    args = ap.parse_args()
    if args.participation_sweep:
        participation_sweep(args.participation_n, args.rounds, args.model)
    else:
        main(tuple(int(c) for c in args.clients.split(",")), args.rounds,
             args.model)
