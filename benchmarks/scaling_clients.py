"""Client-scaling benchmark: round wall-clock vs N, sequential vs vectorized.

The paper's scalability claim is that CoRS cost does not blow up with the
number of users; the sequential simulation harness did (one Python dispatch
chain — relay, jitted update, EAGER upload computation — per client per
round). This measures the post-compile wall-clock of a full round (relay,
local updates, uploads, merge, eval) for both engines, weak-scaling: fixed
samples per client, so total work grows with N and a perfectly-scaling
engine has flat per-client cost.

Model choice matters for what you measure:
  - "mlp" (default): cheap per-client compute, so the number isolates the
    ENGINE overhead the vectorized path removes — this is where the
    >= 3x @ 32-clients acceptance bar applies.
  - "cnn": the paper's LeNet. On a few-core CPU its conv FLOPs saturate the
    machine under either engine, so the ratio measures compute batching
    (~1.1-1.6x here), not dispatch; on accelerators the batched path wins.

  PYTHONPATH=src python -m benchmarks.scaling_clients \
      [--clients 2,8,32,128] [--model mlp|cnn] [--rounds 3]

CSV to stdout: model,n_clients,engine,s_per_round,speedup_vs_seq.
"""
from __future__ import annotations

import argparse
import os
import time

from benchmarks import common
from repro.data import synthetic

PER_CLIENT = int(os.environ.get("REPRO_SCALE_PER_CLIENT", "64"))
N_TEST = int(os.environ.get("REPRO_SCALE_TEST", "1024"))
SEQ_MAX = int(os.environ.get("REPRO_SCALE_SEQ_MAX", "64"))


def time_rounds(trainer, rounds: int = 3) -> float:
    """Seconds per round, excluding the first (compile) round."""
    trainer.run_round()
    t0 = time.perf_counter()
    for _ in range(rounds):
        trainer.run_round()
    return (time.perf_counter() - t0) / rounds


def bench(n_clients: int, engine: str, model: str, rounds: int) -> float:
    train = synthetic.class_images(PER_CLIENT * n_clients, seed=0, noise=0.8)
    test = synthetic.class_images(N_TEST, seed=99, noise=0.8)
    tr = common.make_trainer("cors", n_clients, engine=engine, model=model,
                             batch_size=16, train_data=train, test_data=test)
    return time_rounds(tr, rounds)


def main(clients=(2, 8, 32, 128), rounds: int = 3, model: str = "mlp"):
    print("model,n_clients,engine,s_per_round,speedup_vs_seq")
    results = {}
    for n in clients:
        t_vec = bench(n, "vec", model, rounds)
        if n <= SEQ_MAX:
            t_seq = bench(n, "seq", model, rounds)
            results[n] = t_seq / t_vec
            print(f"{model},{n},seq,{t_seq:.4f},1.00")
            print(f"{model},{n},vec,{t_vec:.4f},{results[n]:.2f}")
        else:
            results[n] = None
            print(f"{model},{n},seq,skipped,")
            print(f"{model},{n},vec,{t_vec:.4f},")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", default="2,8,32,128")
    ap.add_argument("--model", default="mlp", choices=["mlp", "cnn"])
    ap.add_argument("--rounds", type=int, default=3)
    args = ap.parse_args()
    main(tuple(int(c) for c in args.clients.split(",")), args.rounds,
         args.model)
