"""Client-scaling benchmark: round wall-clock vs N, sequential vs vectorized.

The paper's scalability claim is that CoRS cost does not blow up with the
number of users; the sequential simulation harness did (one Python dispatch
chain — relay, jitted update, EAGER upload computation — per client per
round). This measures the post-compile wall-clock of a full round (relay,
local updates, uploads, merge, eval) for both engines, weak-scaling: fixed
samples per client, so total work grows with N and a perfectly-scaling
engine has flat per-client cost.

Model choice matters for what you measure:
  - "mlp" (default): cheap per-client compute, so the number isolates the
    ENGINE overhead the vectorized path removes — this is where the
    >= 3x @ 32-clients acceptance bar applies.
  - "cnn": the paper's LeNet. On a few-core CPU its conv FLOPs saturate the
    machine under either engine, so the ratio measures compute batching
    (~1.1-1.6x here), not dispatch; on accelerators the batched path wins.

  PYTHONPATH=src python -m benchmarks.scaling_clients \
      [--clients 2,8,32,128] [--model mlp|cnn] [--rounds 3] \
      [--participation-sweep] [--participation-n 32] \
      [--hetero [--mix mlp:32,mlp:64] [--hetero-n 32]] \
      [--async-sweep [--async-n 32]] \
      [--download-lag [--download-lag-n 32]] \
      [--population-sweep [--populations 1000,...,1000000] \
          [--population-seats 8] [--population-shards 2]] \
      [--ci-gate [--out BENCH_ci.json] [--floor benchmarks/ci_floor.json]]

CSV to stdout: model,n_clients,engine,s_per_round,speedup_vs_seq.

--participation-sweep instead measures partial client rounds (the
relay/participation subsystem): at fixed N, k/N ∈ {0.25, 0.5, 1.0} clients
per round via the uniform_k schedule. The vectorized engine compacts the
round step to the k participants, so both wall-clock AND comm volume per
round should fall ≈ linearly with k/N.
CSV: model,n_clients,k,s_per_round,comm_mb_per_round,speedup_vs_full.

--hetero measures the BUCKETED engine on a mixed-architecture fleet
(`common.hetero_fleet` mix spec, clients assigned round-robin so buckets
interleave): one vmapped round step per bucket around the shared relay, vs
the sequential oracle stepping every client individually. Same weak-scaling
setup; the speedup column is the mixed-fleet vec-over-seq ratio.
CSV: mix,n_clients,n_buckets,engine,s_per_round,speedup_vs_seq.

--async-sweep measures the asynchronous event-ordered relay
(repro.relay.events + repro.sim clocks): at fixed N, a lognormal straggler
clock with D_max in {0, 1, 4} — D_max=0 is the synchronous fast path
(baseline), larger D_max pays for the pending-buffer commit inside the
jitted round step. The speedup column is vec-over-seq at the SAME D_max,
so it tracks whether the async engine keeps its vectorization win.
CSV: model,n_clients,d_max,engine,s_per_round,speedup_vs_seq.

--download-lag measures the download-lag relay history
(repro.relay.history + repro.sim download clocks): at fixed N, a lognormal
download clock with D_max in {0, 1, 4} — clients read stale snapshots from
a ring of H_max = D_max + 1 post-merge states. D_max=0 is the round-fresh
fast path (baseline); larger D_max pays for the in-step snapshot gather +
ring push, which should leave vec per-round cost ~flat in H_max.
CSV: model,n_clients,dl_max,engine,s_per_round,speedup_vs_seq.

--population-sweep measures the POPULATION-scale claim (cohort shards +
streaming arrivals, repro.relay.shards + repro.sim.population): hold the
active cohort (seats), participation (k) and relay shard count (S) fixed
while the total client population grows 10^3 -> 10^6. Per-round
wall-clock and resident state (relay shards + cohort seat table) must
stay flat — cost follows the cohort, never the id space — and the
per-shard occupancy/diversity report (repro.obs.shard_summary) surfaces
hash skew. CSV: model,population,seats,k,shards,s_per_round,state_mb.

--ci-gate is the CI benchmark-regression job (.github/workflows/ci.yml):
run the tiny committed configs from benchmarks/ci_floor.json (N=8 MLP
sync, an async lognormal entry, a download-lag entry, and the telemetry
on-vs-off overhead entry — repro.obs must stay within its committed
overhead ceiling when on), write the measurements to BENCH_ci.json plus
the telemetry run's BENCH_telemetry.jsonl / BENCH_trace.json (uploaded
as CI artifacts), and exit 1 if any vec-over-seq per-round speedup falls
below its committed floor or the telemetry overhead exceeds its ceiling.
Re-baselining is documented in ci_floor.json itself and ROADMAP.md.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

from benchmarks import common
from repro.data import synthetic

PER_CLIENT = int(os.environ.get("REPRO_SCALE_PER_CLIENT", "64"))
N_TEST = int(os.environ.get("REPRO_SCALE_TEST", "1024"))
SEQ_MAX = int(os.environ.get("REPRO_SCALE_SEQ_MAX", "64"))


def _block_round_state(trainer):
    """Barrier on the trainer's device-side round outputs: relay state and
    client params (per bucket for hetero fleets; the oracle's per-client
    states otherwise). run_round returns after DISPATCH, so a timed loop
    without this would count Python dispatch and drop the last round's
    in-flight device work."""
    import jax
    targets = []
    if hasattr(trainer, "relay_state"):          # vectorized engine
        targets.append(trainer.relay_state)
        targets.append([b.params for b in trainer.buckets]
                       if trainer.hetero else trainer.params)
    else:                                        # sequential oracle
        targets.append(trainer.server.state)
        targets.append([c.params for c in trainer.clients])
    jax.block_until_ready(targets)


def time_rounds(trainer, rounds: int = 3) -> float:
    """Seconds per round, excluding the first round — the warm-up that
    absorbs jit tracing + compilation. Both the warm-up and the timed loop
    end on a `_block_round_state` barrier so the clock starts from an idle
    device and stops only when the last round's work actually finished."""
    trainer.run_round()
    _block_round_state(trainer)
    t0 = time.perf_counter()
    for _ in range(rounds):
        trainer.run_round()
    _block_round_state(trainer)
    return (time.perf_counter() - t0) / rounds


def bench(n_clients: int, engine: str, model: str, rounds: int,
          hetero: str = None, per_client: int = None,
          clock: str = None, download_clock: str = None,
          mesh_devices: int = 0, policy: str = None, arrivals: str = None,
          telemetry=None) -> float:
    pc = per_client or PER_CLIENT
    train = synthetic.class_images(pc * n_clients, seed=0, noise=0.8)
    test = synthetic.class_images(N_TEST, seed=99, noise=0.8)
    mesh = None
    if mesh_devices and engine == "vec":
        from repro import sharding
        mesh = sharding.client_mesh(mesh_devices)
    tr = common.make_trainer("cors", n_clients, engine=engine, model=model,
                             batch_size=16, train_data=train, test_data=test,
                             hetero=hetero, clock=clock,
                             download_clock=download_clock, mesh=mesh,
                             policy=policy, arrivals=arrivals,
                             telemetry=telemetry)
    return time_rounds(tr, rounds)


def async_sweep(n_clients: int = 32, rounds: int = 3, model: str = "mlp"):
    """Bounded-delay relay cost: vec vs seq per round at D_max in
    {0, 1, 4} under a lognormal straggler clock. D_max=0 routes to the
    synchronous fast path; D_max>0 runs the full-width async step with the
    (N, D_max, ...) pending buffer, so the column shows what event-ordered
    lateness costs and whether the vectorization win survives it."""
    print("model,n_clients,d_max,engine,s_per_round,speedup_vs_seq")
    results = {}
    for d_max in (0, 1, 4):
        clock = None if d_max == 0 else f"lognormal:{d_max}"
        t_vec = bench(n_clients, "vec", model, rounds, clock=clock)
        t_seq = bench(n_clients, "seq", model, rounds, clock=clock)
        results[d_max] = t_seq / t_vec
        print(f"{model},{n_clients},{d_max},seq,{t_seq:.4f},1.00")
        print(f"{model},{n_clients},{d_max},vec,{t_vec:.4f},"
              f"{results[d_max]:.2f}")
    return results


def download_lag_sweep(n_clients: int = 32, rounds: int = 3,
                       model: str = "mlp"):
    """Download-lag relay history cost: vec vs seq per round at download
    D_max in {0, 1, 4} (H_max = D_max + 1 retained snapshots) under a
    lognormal download clock. D_max=0 is the round-fresh fast path
    (baseline, no history machinery); D_max>0 threads the snapshot ring
    through the jitted step — per-client stale reads are one batched
    gather and the push one ring write, so vec per-round cost should stay
    ~flat in H_max while the seq oracle keeps paying its O(N) dispatch
    chain (mirroring the --async-sweep shape). The speedup column is
    vec-over-seq at the SAME D_max.
    CSV: model,n_clients,dl_max,engine,s_per_round,speedup_vs_seq."""
    print("model,n_clients,dl_max,engine,s_per_round,speedup_vs_seq")
    results = {}
    for dl_max in (0, 1, 4):
        dl = None if dl_max == 0 else f"lognormal:{dl_max}"
        t_vec = bench(n_clients, "vec", model, rounds, download_clock=dl)
        t_seq = bench(n_clients, "seq", model, rounds, download_clock=dl)
        results[dl_max] = t_seq / t_vec
        print(f"{model},{n_clients},{dl_max},seq,{t_seq:.4f},1.00")
        print(f"{model},{n_clients},{dl_max},vec,{t_vec:.4f},"
              f"{results[dl_max]:.2f}")
    return results


def hetero_sweep(n_clients: int = 32, rounds: int = 3,
                 mix: str = "mlp:32,mlp:64"):
    """Mixed-spec fleet: bucketed vectorized engine vs sequential oracle.

    The default mix keeps per-client compute cheap for the same reason the
    homogeneous sweep defaults to "mlp": the ratio then measures the ENGINE
    (O(N) Python dispatch vs one dispatch per bucket). Wider/conv mixes
    (e.g. "mlp:64,mlp:128" or "...,cnn:1") shift both engines toward the
    same compute and the ratio toward XLA's batching efficiency — measured
    ~3.7x for the default vs ~2.6x for "mlp:64,mlp:128" at N=32 on a
    2-core CPU."""
    n_buckets = len(mix.split(","))
    print("mix,n_clients,n_buckets,engine,s_per_round,speedup_vs_seq")
    t_vec = bench(n_clients, "vec", "mlp", rounds, hetero=mix)
    t_seq = bench(n_clients, "seq", "mlp", rounds, hetero=mix)
    speedup = t_seq / t_vec
    print(f"{mix},{n_clients},{n_buckets},seq,{t_seq:.4f},1.00")
    print(f"{mix},{n_clients},{n_buckets},vec,{t_vec:.4f},{speedup:.2f}")
    return speedup


def _population_trainer(engine: str, population: int, seats: int, k: int,
                        shards: int, model: str, per_client: int = None,
                        rate: float = 2.0, p_leave: float = 0.2):
    """A streaming cohort fleet: `seats` concurrently-resident clients
    drawn from a `population`-sized external id space, hashed onto
    `shards` relay shards. Compute, data and relay state are all sized by
    the SEATS — the population enters only through the id draws."""
    pc = per_client or PER_CLIENT
    train = synthetic.class_images(pc * seats, seed=0, noise=0.8)
    test = synthetic.class_images(N_TEST, seed=99, noise=0.8)
    return common.make_trainer(
        "cors", seats, engine=engine, model=model, batch_size=16,
        train_data=train, test_data=test,
        policy=f"sharded:flat,{shards}",
        arrivals=f"stream:{k},{rate},{p_leave},{population},0")


def _population_state_mb(tr) -> float:
    """Resident bytes that COULD scale with the population: the relay
    state (all shards) plus the cohort seat table."""
    import jax
    state = tr.relay_state if hasattr(tr, "relay_state") else tr.server.state
    nbytes = sum(leaf.nbytes for leaf in jax.tree.leaves(state))
    return (nbytes + tr._cohort.nbytes()) / 1e6


def population_sweep(populations=(10**3, 10**4, 10**5, 10**6),
                     seats: int = 8, k: int = 2, shards: int = 2,
                     rounds: int = 12, model: str = "mlp",
                     tolerance: float = 0.2, reps: int = 2):
    """The paper's N-independence claim at population scale: hold the
    active cohort (seats), participation (k) and shard count (S) fixed
    while the TOTAL population grows 10^3 -> 10^6. Per-round wall-clock
    and resident state must stay flat (within `tolerance`): cost follows
    the cohort, never the id space. Also prints the per-shard
    occupancy/diversity/commit-lag report (repro.obs.shard_summary) for
    the largest population — the observability surface for shard skew.
    Each point is the best of `reps` timed windows on the same compiled
    trainer: percent-level flatness needs sub-noise timings, and ~40ms
    rounds on a shared 2-core runner drift more than 20% run to run.
    CSV: model,population,seats,k,shards,s_per_round,state_mb."""
    from repro import obs
    print("model,population,seats,k,shards,s_per_round,state_mb")
    results, last = {}, None
    for pop in populations:
        tr = _population_trainer("vec", pop, seats, k, shards, model)
        t = min(time_rounds(tr, rounds) for _ in range(max(1, reps)))
        mb = _population_state_mb(tr)
        results[pop] = {"s_per_round": t, "state_mb": mb}
        print(f"{model},{pop},{seats},{k},{shards},{t:.4f},{mb:.3f}")
        last = tr
    times = [r["s_per_round"] for r in results.values()]
    spread = max(times) / min(times) - 1.0
    mbs = [r["state_mb"] for r in results.values()]
    mem_spread = max(mbs) / min(mbs) - 1.0
    flat = spread <= tolerance and mem_spread <= tolerance
    print(f"population-sweep: time spread {spread:.1%}, memory spread "
          f"{mem_spread:.1%} over N={populations[0]}..{populations[-1]} "
          f"[{'FLAT' if flat else 'NOT FLAT'}] (tolerance {tolerance:.0%})")
    shard_rep = obs.shard_summary(last.relay_state)
    print(f"per-shard occupancy {shard_rep['occupancy']}, owner diversity "
          f"{shard_rep['owner_diversity']}")
    return {"results": results, "time_spread": spread,
            "memory_spread": mem_spread, "flat": flat,
            "shards": shard_rep}


def _measure_entry(cfg) -> tuple:
    """(t_vec, t_seq) for one gate entry config. A "devices" key runs the
    vec side on a forced multi-device mesh (the placement path,
    repro.relay.placement); the seq oracle is meshless either way. A
    "policy"/"arrivals" pair runs the cohort-sharded streaming fleet
    (the population entry)."""
    kw = dict(per_client=cfg["per_client"], clock=cfg.get("clock"),
              download_clock=cfg.get("download_clock"),
              policy=cfg.get("policy"), arrivals=cfg.get("arrivals"))
    t_vec = bench(cfg["n_clients"], "vec", cfg["model"], cfg["rounds"],
                  mesh_devices=int(cfg.get("devices", 0)), **kw)
    t_seq = bench(cfg["n_clients"], "seq", cfg["model"], cfg["rounds"], **kw)
    return t_vec, t_seq


def _probe_subprocess(name: str, floor_path: str, devices: int) -> tuple:
    """Re-run ONE gate entry in a child interpreter with XLA forced to
    `devices` virtual host devices (the flag must be set before the first
    jax import, so the parent process cannot measure it itself)."""
    import subprocess
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={devices}"
                        ).strip()
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.scaling_clients",
         "--gate-probe", name, "--floor", floor_path],
        env=env, capture_output=True, text=True, check=True)
    probe = json.loads(out.stdout.strip().splitlines()[-1])
    return probe["t_vec"], probe["t_seq"]


def gate_probe(name: str, floor_path: str) -> int:
    """Child side of _probe_subprocess: measure one entry, print JSON."""
    with open(floor_path) as f:
        floor = json.load(f)
    cfg = (floor if name == "sync" else floor[name])["config"]
    t_vec, t_seq = _measure_entry(cfg)
    print(json.dumps({"t_vec": t_vec, "t_seq": t_seq}))
    return 0


def _measure_telemetry(cfg, jsonl_path: str, trace_path: str) -> tuple:
    """(t_off, t_on): vec per-round seconds with telemetry fully off vs
    fully ON (in-jit metrics + JSONL sink + trace recorder — the whole
    opt-in surface, which also leaves the gate's artifacts behind for CI
    upload). Best of `reps` interleaved pairs, ALTERNATING which side of
    the pair runs first: machine drift within a pair (thermal, page
    cache) otherwise lands systematically on the second side and reads as
    fake overhead — measured ~5% of bias on a 2-core container, the same
    order as the real overhead this gate bounds."""
    from repro import obs
    kw = dict(per_client=cfg["per_client"])
    on_cfg = obs.TelemetryConfig(jsonl=jsonl_path, trace=trace_path)
    t_off = t_on = float("inf")
    for rep in range(int(cfg.get("reps", 4))):
        order = [(None, False), (on_cfg, True)]
        if rep % 2:
            order.reverse()
        for telem, is_on in order:
            t = bench(cfg["n_clients"], "vec", cfg["model"], cfg["rounds"],
                      telemetry=telem, **kw)
            if is_on:
                t_on = min(t_on, t)
            else:
                t_off = min(t_off, t)
    return t_off, t_on


def ci_gate(out: str = "BENCH_ci.json",
            floor_path: str = "benchmarks/ci_floor.json") -> int:
    """The CI benchmark-regression gate. Measures every committed tiny
    config (the synchronous top-level entry plus any named extra entries,
    e.g. "async", or "mesh" — the placement path on forced virtual
    devices) and fails (exit 1) when any vec-over-seq speedup drops below
    its committed floor. A "telemetry" entry gates the observability
    layer's cost instead: vec rounds with the full telemetry surface on
    must stay within `max_overhead_on_over_off` of telemetry-off rounds
    (the "cheap when on" contract), and the measurement's JSONL/trace
    artifacts are written next to `out` for CI upload."""
    import jax
    with open(floor_path) as f:
        floor = json.load(f)
    entries = [("sync", floor)] + [
        (name, floor[name])
        for name in ("async", "download_lag", "mesh", "population")
        if name in floor]
    result, failed = {}, []
    for name, entry in entries:
        cfg = entry["config"]
        devices = int(cfg.get("devices", 0))
        if devices > jax.local_device_count():
            t_vec, t_seq = _probe_subprocess(name, floor_path, devices)
        else:
            t_vec, t_seq = _measure_entry(cfg)
        speedup = t_seq / t_vec
        min_speedup = entry["min_speedup_vec_over_seq"]
        ok = speedup >= min_speedup
        result[name] = {"config": cfg, "s_per_round_seq": t_seq,
                        "s_per_round_vec": t_vec,
                        "speedup_vec_over_seq": speedup,
                        "min_speedup_vec_over_seq": min_speedup,
                        "passed": ok}
        print(f"ci-gate[{name}]: vec {t_vec:.4f}s/round, seq "
              f"{t_seq:.4f}s/round -> {speedup:.2f}x (floor "
              f"{min_speedup}x) [{'PASS' if ok else 'FAIL'}]")
        if not ok:
            failed.append((name, f"vec-over-seq speedup {speedup:.2f}x is "
                                 f"below the committed floor {min_speedup}x"))
    if "population" in floor:
        # flatness artifact: a two-point population sweep (10^3 vs 10^6 at
        # the gate's seats/k/S) written next to `out` for CI upload; a
        # generous max_spread bounds wall-clock noise while still failing
        # a real O(population) regression (which shows up as ~10^3x).
        entry = floor["population"]
        cfg = entry["config"]
        sweep = population_sweep(
            populations=tuple(cfg.get("report_populations",
                                      (10**3, 10**6))),
            seats=cfg["n_clients"], k=int(cfg.get("k", 2)),
            shards=int(cfg.get("shards", 2)),
            rounds=int(cfg.get("report_rounds", 12)),
            model=cfg["model"], tolerance=entry.get("max_spread", 0.5))
        pop_out = os.path.join(os.path.dirname(os.path.abspath(out)),
                               "BENCH_population.json")
        with open(pop_out, "w") as f:
            json.dump(sweep, f, indent=2)
        result["population"]["sweep"] = pop_out
        result["population"]["time_spread"] = sweep["time_spread"]
        result["population"]["flat"] = sweep["flat"]
        if "max_spread" in entry and not sweep["flat"]:
            result["population"]["passed"] = False
            failed.append(
                ("population", f"per-round cost/memory is not flat in the "
                               f"population: time spread "
                               f"{sweep['time_spread']:.1%}, memory spread "
                               f"{sweep['memory_spread']:.1%} exceed "
                               f"max_spread {entry['max_spread']:.0%}"))
    if "telemetry" in floor:
        entry = floor["telemetry"]
        base = os.path.dirname(os.path.abspath(out))
        jsonl_path = os.path.join(base, "BENCH_telemetry.jsonl")
        trace_path = os.path.join(base, "BENCH_trace.json")
        t_off, t_on = _measure_telemetry(entry["config"], jsonl_path,
                                         trace_path)
        overhead = t_on / t_off
        max_over = entry["max_overhead_on_over_off"]
        ok = overhead <= max_over
        result["telemetry"] = {"config": entry["config"],
                               "s_per_round_off": t_off,
                               "s_per_round_on": t_on,
                               "overhead_on_over_off": overhead,
                               "max_overhead_on_over_off": max_over,
                               "jsonl": jsonl_path, "trace": trace_path,
                               "passed": ok}
        print(f"ci-gate[telemetry]: off {t_off:.4f}s/round, on "
              f"{t_on:.4f}s/round -> {overhead:.2f}x (ceiling "
              f"{max_over}x) [{'PASS' if ok else 'FAIL'}]")
        if not ok:
            failed.append(
                ("telemetry", f"telemetry-on rounds cost {overhead:.2f}x "
                              f"telemetry-off, above the committed ceiling "
                              f"{max_over}x"))
    result["passed"] = not failed
    with open(out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"ci-gate: {'PASS' if not failed else 'FAIL'} -> {out}")
    for name, why in failed:
        print(f"ci-gate: FAIL[{name}] — {why} ({floor_path}). Either a "
              "perf regression, or the floor needs re-baselining (see "
              "that file).", file=sys.stderr)
    return 1 if failed else 0


def participation_sweep(n_clients: int = 32, rounds: int = 3,
                        model: str = "mlp", fractions=(0.25, 0.5, 1.0)):
    """Partial-round savings: s/round and comm/round vs participants k."""
    train = synthetic.class_images(PER_CLIENT * n_clients, seed=0, noise=0.8)
    test = synthetic.class_images(N_TEST, seed=99, noise=0.8)
    print("model,n_clients,k,s_per_round,comm_mb_per_round,speedup_vs_full")
    results = {}
    t_full = None
    for frac in sorted(fractions, reverse=True):     # full first (baseline)
        k = max(1, int(round(frac * n_clients)))
        tr = common.make_trainer(
            "cors", n_clients, engine="vec", model=model, batch_size=16,
            train_data=train, test_data=test,
            participation=f"uniform_k:{k}")
        t = time_rounds(tr, rounds)
        up, down = tr.ledger.by_round[-1]
        comm_mb = 4 * (up + down) / 1e6
        if t_full is None:
            t_full = t
        results[k] = (t, comm_mb, t_full / t)
        print(f"{model},{n_clients},{k},{t:.4f},{comm_mb:.4f},"
              f"{t_full / t:.2f}")
    return results


def main(clients=(2, 8, 32, 128), rounds: int = 3, model: str = "mlp"):
    print("model,n_clients,engine,s_per_round,speedup_vs_seq")
    results = {}
    for n in clients:
        t_vec = bench(n, "vec", model, rounds)
        if n <= SEQ_MAX:
            t_seq = bench(n, "seq", model, rounds)
            results[n] = t_seq / t_vec
            print(f"{model},{n},seq,{t_seq:.4f},1.00")
            print(f"{model},{n},vec,{t_vec:.4f},{results[n]:.2f}")
        else:
            results[n] = None
            print(f"{model},{n},seq,skipped,")
            print(f"{model},{n},vec,{t_vec:.4f},")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", default="2,8,32,128")
    ap.add_argument("--model", default="mlp", choices=["mlp", "cnn"])
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--participation-sweep", action="store_true",
                    help="measure partial rounds (k/N in {0.25,0.5,1.0}) "
                         "instead of the seq-vs-vec engine scaling")
    ap.add_argument("--participation-n", type=int, default=32,
                    help="N for the participation sweep")
    ap.add_argument("--hetero", action="store_true",
                    help="measure a mixed-architecture fleet through the "
                         "bucketed engine vs the sequential oracle")
    ap.add_argument("--mix", default="mlp:32,mlp:64",
                    help="hetero mix spec (common.hetero_fleet), e.g. "
                         "mlp:32,mlp:64 or mlp:64,mlp:96,cnn:1")
    ap.add_argument("--hetero-n", type=int, default=32,
                    help="N for the hetero sweep")
    ap.add_argument("--async-sweep", action="store_true",
                    help="measure the asynchronous event-ordered relay "
                         "(lognormal straggler clock, D_max in {0,1,4}) "
                         "vec vs seq")
    ap.add_argument("--async-n", type=int, default=32,
                    help="N for the async sweep")
    ap.add_argument("--download-lag", action="store_true",
                    help="measure the download-lag history ring (lognormal "
                         "download clock, D_max in {0,1,4} i.e. H_max up "
                         "to 5) vec vs seq")
    ap.add_argument("--download-lag-n", type=int, default=32,
                    help="N for the download-lag sweep")
    ap.add_argument("--population-sweep", action="store_true",
                    help="hold seats/k/S fixed and grow the total "
                         "population 10^3 -> 10^6: per-round cost and "
                         "resident state must stay flat")
    ap.add_argument("--populations", default="1000,10000,100000,1000000",
                    help="population sizes for the population sweep")
    ap.add_argument("--population-seats", type=int, default=8,
                    help="active-cohort seats for the population sweep")
    ap.add_argument("--population-shards", type=int, default=2,
                    help="relay shard count for the population sweep")
    ap.add_argument("--ci-gate", action="store_true",
                    help="run the CI benchmark-regression gate (config + "
                         "floor from --floor; exit 1 below the floor)")
    ap.add_argument("--out", default="BENCH_ci.json",
                    help="ci-gate: where to write the measurement JSON")
    ap.add_argument("--floor", default="benchmarks/ci_floor.json",
                    help="ci-gate: committed config + speedup floor")
    ap.add_argument("--gate-probe", default=None, metavar="ENTRY",
                    help=argparse.SUPPRESS)   # ci_gate internal (subprocess)
    args = ap.parse_args()
    if args.gate_probe:
        sys.exit(gate_probe(args.gate_probe, args.floor))
    if args.ci_gate:
        sys.exit(ci_gate(args.out, args.floor))
    elif args.population_sweep:
        population_sweep(
            tuple(int(p) for p in args.populations.split(",")),
            seats=args.population_seats, shards=args.population_shards,
            rounds=args.rounds, model=args.model)
    elif args.download_lag:
        download_lag_sweep(args.download_lag_n, args.rounds, args.model)
    elif args.async_sweep:
        async_sweep(args.async_n, args.rounds, args.model)
    elif args.hetero:
        hetero_sweep(args.hetero_n, args.rounds, args.mix)
    elif args.participation_sweep:
        participation_sweep(args.participation_n, args.rounds, args.model)
    else:
        main(tuple(int(c) for c in args.clients.split(",")), args.rounds,
             args.model)
