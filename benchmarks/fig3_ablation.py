"""Paper Fig. 3: λ_KD × λ_disc ablation grid — test-accuracy improvement [%]
over IL (upper-left corner of the grid = IL)."""
from __future__ import annotations


from benchmarks import common

GRID_KD = (0.0, 1.0, 10.0)
GRID_DISC = (0.0, 0.1, 1.0)


def main(n_clients=5, rounds=None):
    base = common.run_mode("il", n_clients, rounds)
    il_acc = base.history[-1]["acc_mean"]
    print("lambda_kd,lambda_disc,acc,improvement_pct_vs_IL")
    print(f"0.0,0.0,{il_acc:.4f},0.00")
    out = {}
    for kd in GRID_KD:
        for dc in GRID_DISC:
            if kd == 0.0 and dc == 0.0:
                continue
            tr = common.run_mode("cors", n_clients, rounds, lambda_kd=kd,
                                 lambda_disc=dc)
            acc = tr.history[-1]["acc_mean"]
            imp = (acc - il_acc) * 100
            out[(kd, dc)] = imp
            print(f"{kd},{dc},{acc:.4f},{imp:+.2f}")
    return il_acc, out


if __name__ == "__main__":
    main()
