"""Paper §Communication: per-round uplink volume of CoRS vs FD vs FedAvg vs
SL across the paper's three model scales, plus the measured ledger of a real
round. Validates the '≈1000× fewer bits than FL for ResNet9' claim exactly.
"""
from __future__ import annotations

import jax

from repro.core import comm
from repro.models import cnn

MODELS = {
    # (params, d_feature) — paper's three experiment scales
    "LeNet5": (30_000, 84),
    "ResNet9": (2_400_000, 128),
    "ResNet18": (11_300_000, 256),
}
C = 10
N = 5
N_SAMPLES = 1200 // N


def main():
    print("model,scheme,up_floats_per_round_per_client,ratio_vs_cors")
    for name, (D, d) in MODELS.items():
        cors_up, _ = comm.cors_round_floats(C, d, 1, 1, 1)
        fd_up, _ = comm.fd_round_floats(C, 1)
        fl_up, _ = comm.fedavg_round_floats(D, 1)
        sl_up, _ = comm.sl_epoch_floats(N_SAMPLES, d, 1)
        for scheme, v in (("CoRS", cors_up), ("FD", fd_up), ("FedAvg", fl_up),
                          ("SL", sl_up)):
            print(f"{name},{scheme},{v},{v / cors_up:.1f}")
    # measured: one real CoRS round with the actual LeNet-style CNN
    params = cnn.init_cnn(jax.random.PRNGKey(0))
    D_real = cnn.num_params(params)
    cors_up, _ = comm.cors_round_floats(C, 84, 1, 1, 1)
    fl_up, _ = comm.fedavg_round_floats(D_real, 1)
    print(f"measured-CNN(D={D_real}),FedAvg/CoRS ratio,"
          f"{fl_up / cors_up:.2f},-")
    return {"lenet_ratio": fl_up / cors_up}


if __name__ == "__main__":
    main()
