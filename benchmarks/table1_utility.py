"""Paper Table 1: average test accuracy of CL / FL / IL / FD / Ours after r
rounds, same data split uniformly across N users.

Paper setting: MNIST 1200 samples, LeNet5-like CNN, r = 100. Here: synthetic
class-conditional multi-mode images (DESIGN.md §6), same sample budget, same
model family, r = REPRO_BENCH_ROUNDS (env).
"""
from __future__ import annotations



from benchmarks import common


def main(n_values=(2, 5), rounds=None):
    rows = []
    print("framework,N,rounds,acc_mean,acc_std,comm_MB")
    cl = common.run_mode("cl", 1, rounds)
    rec = cl.history[-1]
    print(f"CL,1,{rec['round']},{rec['acc_mean']:.4f},{rec['acc_std']:.4f},0.0")
    rows.append(("CL", 1, rec["acc_mean"]))
    for N in n_values:
        for mode, label in (("fedavg", "FL"), ("il", "IL"), ("fd", "FD"),
                            ("cors", "Ours")):
            tr = common.run_mode(mode, N, rounds)
            rec = tr.history[-1]
            mb = tr.ledger.total_bytes / 1e6
            print(f"{label},{N},{rec['round']},{rec['acc_mean']:.4f},"
                  f"{rec['acc_std']:.4f},{mb:.3f}")
            rows.append((label, N, rec["acc_mean"]))
    return rows


if __name__ == "__main__":
    main()
