"""Kernel micro-benchmarks: wall time of the portable (ref) path on CPU plus
derived arithmetic intensity. TPU timings come from real hardware; here the
CSV documents call cost of the exact shapes the CoRS loop uses."""
from __future__ import annotations

import jax

from benchmarks.common import timeit
from repro.kernels import ref

KEY = jax.random.PRNGKey(0)


def main():
    print("name,us_per_call,derived")
    # flash attention at CoRS-training shape (per-device tile)
    q = jax.random.normal(KEY, (4, 512, 8, 64))
    k = jax.random.normal(KEY, (4, 512, 2, 64))
    v = jax.random.normal(KEY, (4, 512, 2, 64))
    fn = jax.jit(lambda a, b, c: ref.flash_attention(a, b, c, causal=True))
    us = timeit(fn, q, k, v, iters=5)
    flops = 4 * 512 * 512 * 8 * 64 * 2 * 2
    print(f"flash_attention_b4s512h8,{us:.1f},{flops/us*1e-6:.2f}GFLOP/s")

    # proto accumulation at CNN scale and at LM-vocab scale
    for (n, d, C, tag) in ((1024, 84, 10, "cnn"), (8192, 512, 4096, "lm")):
        f = jax.random.normal(KEY, (n, d))
        l = jax.random.randint(KEY, (n,), 0, C)
        fn = jax.jit(lambda a, b: ref.proto_accum(a, b, C))
        us = timeit(fn, f, l, iters=5)
        print(f"proto_accum_{tag}_n{n}_C{C},{us:.1f},"
              f"{n*C*d*2/us*1e-6:.2f}GFLOP/s")

    # fused disc loss at paper scale and sampled-LM scale
    for (B, C, M, tag) in ((320, 10, 10, "paper"), (2048, 4096, 256, "lm")):
        s = jax.random.normal(KEY, (B, C))
        qm = jax.nn.softmax(jax.random.normal(KEY, (M, C)), -1)
        y = jax.random.randint(KEY, (B,), 0, M)
        fn = jax.jit(lambda a, b, c: ref.disc_loss(a, b, c))
        us = timeit(fn, s, qm, y, iters=5)
        print(f"disc_loss_{tag}_B{B}_C{C},{us:.1f},"
              f"{B*M*C*2/us*1e-6:.2f}GFLOP/s")
    return True


if __name__ == "__main__":
    main()
