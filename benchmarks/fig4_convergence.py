"""Paper Fig. 4: test-accuracy convergence curves (per round) for IL / FL /
FD / Ours, with ±std across clients."""
from __future__ import annotations

from benchmarks import common


def main(n_clients=5, rounds=None):
    print("framework,round,acc_mean,acc_std")
    curves = {}
    for mode, label in (("il", "IL"), ("fedavg", "FL"), ("fd", "FD"),
                        ("cors", "Ours")):
        tr = common.run_mode(mode, n_clients, rounds)
        curves[label] = [(h["round"], h["acc_mean"], h["acc_std"])
                         for h in tr.history]
        for r, a, s in curves[label]:
            print(f"{label},{r},{a:.4f},{s:.4f}")
    return curves


if __name__ == "__main__":
    main()
