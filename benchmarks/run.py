"""Benchmark entry point: one module per paper table/figure.

  python -m benchmarks.run [--quick|--full]

CSV lines go to stdout: ``name,us_per_call,derived`` for micro-benches;
per-table CSVs for the paper reproductions. REPRO_BENCH_ROUNDS controls the
round budget of the utility tables (default 12 here; EXPERIMENTS.md numbers
use the dedicated longer runs recorded there).
"""
from __future__ import annotations

import os
import sys
import time


def main() -> None:
    quick = "--full" not in sys.argv
    if quick and "REPRO_BENCH_ROUNDS" not in os.environ:
        os.environ["REPRO_BENCH_ROUNDS"] = "12"
    from benchmarks import (comm_cost, fig3_ablation, fig4_convergence,
                            kernel_bench, roofline_table, scaling_clients,
                            table1_utility)
    t0 = time.time()
    print("== comm_cost (paper §Communication) ==")
    comm_cost.main()
    print("\n== kernel micro-benchmarks ==")
    kernel_bench.main()
    print("\n== roofline table (deliverable g, from dry-run artifacts) ==")
    roofline_table.main()
    print("\n== table1_utility (paper Table 1) ==")
    table1_utility.main(n_values=(2, 5) if quick else (2, 5, 10))
    print("\n== fig4_convergence (paper Fig. 4) ==")
    fig4_convergence.main(n_clients=5)
    print("\n== scaling_clients (vectorized engine vs sequential oracle) ==")
    scaling_clients.main(clients=(2, 8, 32) if quick else (2, 8, 32, 128))
    print("\n== participation sweep (partial client rounds, k/N savings) ==")
    scaling_clients.participation_sweep(n_clients=16 if quick else 32)
    if not quick:
        print("\n== fig3_ablation (paper Fig. 3) ==")
        fig3_ablation.main(n_clients=5)
    print(f"\n== benchmarks done in {time.time()-t0:.0f}s ==")


if __name__ == '__main__':
    main()
