"""End-to-end serving driver: prefill a batch of prompts, then decode tokens
with the KV cache — the same `prefill_step` / `decode_step` that the
decode_32k / long_500k dry-runs lower, on a small model at CPU scale.

  PYTHONPATH=src python examples/serve_lm.py [--arch xlstm-125m] [--tokens N]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.data import synthetic
from repro.launch import serve as serve_lib
from repro.models import lm


def _grow_caches(cfg, caches, extra: int):
    """Extend every attention-cache seq axis by `extra` empty slots."""
    from repro.models import blocks

    def pad(c, axis):
        return jax.tree.map(
            lambda a: jnp.pad(a, [(0, extra if i == axis else 0)
                                  for i in range(a.ndim)]), c)

    out = {"segments": [], "shared": []}
    for (kind, _), c in zip(blocks.segments_of(cfg), caches["segments"]):
        out["segments"].append(pad(c, 2) if kind == "attn" else c)
    for c in caches["shared"]:
        out["shared"].append(pad(c, 1))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced(num_layers=2, d_model=256, vocab_size=512)
    print(f"serving {cfg.name}: {cfg.num_layers}L d={cfg.d_model} "
          f"vocab={cfg.vocab_size}")

    key = jax.random.PRNGKey(0)
    params = lm.init_lm(key, cfg)
    stream = synthetic.token_stream(10_000, vocab=cfg.vocab_size, seed=1)
    prompts = np.stack([stream[i * 100:i * 100 + args.prompt_len]
                        for i in range(args.batch)])

    prefill = jax.jit(serve_lib.make_prefill_step(cfg))

    # fixed-size cache = prompt + generation budget; decode writes at
    # cache_index with validity masking -> ONE compile for all steps.
    total = args.prompt_len + args.tokens
    decode = jax.jit(lambda p, b, c, i: lm.decode_step(
        p, cfg, b, c, cache_index=i, masked=True))

    t0 = time.perf_counter()
    out = prefill(params, {"tokens": jnp.asarray(prompts)})
    caches = _grow_caches(cfg, out["caches"], args.tokens)
    logits = out["logits"]
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    generated = []
    t0 = time.perf_counter()
    for i in range(args.tokens):
        nxt = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
        generated.append(np.asarray(nxt)[:, 0])
        out = decode(params, {"tokens": nxt}, caches,
                     jnp.asarray(args.prompt_len + i, jnp.int32))
        logits = out["logits"]
        caches = out["caches"]
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0

    gen = np.stack(generated, axis=1)
    print(f"prefill: {args.batch}x{args.prompt_len} tokens in "
          f"{t_prefill*1e3:.1f} ms")
    print(f"decode : {args.tokens} steps x batch {args.batch} in "
          f"{t_decode*1e3:.1f} ms "
          f"({args.tokens*args.batch/t_decode:.1f} tok/s)")
    print("sample continuation ids:", gen[0][:12])


if __name__ == "__main__":
    main()
