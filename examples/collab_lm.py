"""CoRS across *heterogeneous LM architectures* — the paper's
model-heterogeneity selling point at LM scale: a (reduced) llama-family
client and a (reduced) xLSTM client collaborate purely through per-class
(= per-next-token) feature representations. No weights cross the boundary,
so the architectures never need to match.

The fleet need not be synchronous either: `--clock-model` commits each
client's prototype stats late through the bounded-delay pending buffer
(launch.train.make_async_round_sync — the LM-scale counterpart of the
engines' event-ordered relay), and `--download-clock` serves each client
the global prototypes from a past round via the relay history ring
(src/repro/relay/history.py). `--telemetry-out` streams per-round records
(CE, late/stale counters, prototype drift/mass/coverage from
launch.train.proto_round_telemetry) to a JSONL the run-report CLI renders.

  PYTHONPATH=src python examples/collab_lm.py [--rounds R]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs, sim
from repro.configs import get_arch
from repro.core import losses, prototypes
from repro.data import synthetic
from repro.launch import train as launch_train
from repro.models import lm
from repro.optim import adam_init, adam_update
from repro.relay import history as relay_history
from repro.types import CollabConfig

VOCAB = 256
SEQ = 64
BATCH = 8
STEPS_PER_ROUND = 8


def make_client(arch: str, key):
    cfg = get_arch(arch).reduced(vocab_size=VOCAB)
    params = lm.init_lm(key, cfg)
    return {"cfg": cfg, "params": params, "opt": adam_init(params)}


def local_round(client, batches, proto_means, lam_kd, lam_disc, key):
    cfg = client["cfg"]

    def loss_fn(params, batch, k):
        out = lm.forward(params, cfg, {"tokens": batch["tokens"]})
        feats, logits = out["features"], out["logits"]
        labels = batch["labels"]
        l_ce = losses.ce_loss(logits, labels)
        l_kd = losses.kd_loss(feats, proto_means, labels)
        f = feats.reshape(-1, feats.shape[-1])[:64]
        y = labels.reshape(-1)[:64]
        l_disc = losses.disc_loss_sampled(
            k, f, proto_means, y, params["lm_head"], num_negatives=32,
            student_logits=logits.reshape(-1, VOCAB)[:64])
        return l_ce + lam_kd * l_kd + lam_disc * l_disc, (l_ce, feats, labels)

    @jax.jit
    def step(params, opt, batch, k):
        (_, (ce, feats, labels)), g = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, k)
        params, opt = adam_update(params, g, opt)
        return params, opt, ce, feats, labels

    stats = prototypes.init_state(VOCAB, cfg.d_model)
    ce = 0.0
    for i, batch in enumerate(batches):
        key, k = jax.random.split(key)
        client["params"], client["opt"], ce, feats, labels = step(
            client["params"], client["opt"], batch, k)
        stats = prototypes.accumulate(stats, feats.reshape(-1, cfg.d_model),
                                      labels.reshape(-1))
    return float(ce), stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--clock-model", default="none",
                    help="virtual-time upload clock (repro.sim): none | "
                         "homogeneous[:delay] | lognormal[:dmax[,sigma]] | "
                         "periodic[:dmax[,period]] — a client's round-r "
                         "prototype stats join the shared state in round "
                         "r+delay via the bounded-delay pending buffer")
    ap.add_argument("--download-clock", default="none",
                    help="download-lag clock (same spec zoo, independent "
                         "randomness): clients read the global prototypes "
                         "of round t-d from the relay history ring instead "
                         "of this round's fresh merge")
    ap.add_argument("--telemetry-out", default=None, metavar="RUN.jsonl",
                    help="stream per-round records (CE, late/stale "
                         "counters, prototype drift) to this JSONL file "
                         "(render with `python -m repro.obs.report "
                         "RUN.jsonl`)")
    args = ap.parse_args()

    keys = jax.random.split(jax.random.PRNGKey(0), 2)
    clients = [make_client("tinyllama-1.1b", keys[0]),
               make_client("xlstm-125m", keys[1])]
    # NOTE: d_model of both reduced archs must match for shared prototypes;
    # reduced() gives 256-dim features for both families here.
    assert clients[0]["cfg"].d_model == clients[1]["cfg"].d_model
    d = clients[0]["cfg"].d_model
    n = len(clients)

    stream = synthetic.token_stream(100_000, vocab=VOCAB, seed=0)
    splits = [stream[:50_000], stream[50_000:]]      # private corpora

    # fleet clocking: the upload clock feeds the bounded-delay pending
    # buffer (late stats commit in their due round, order-free because the
    # prototype merge is a sum); the download clock indexes the history
    # ring of post-merge snapshots. Both degenerate exactly to the
    # synchronous loop at d_max = 0.
    clock = sim.get_clock(args.clock_model, seed=7)
    dl_clock = sim.get_download_clock(args.download_clock, seed=7)
    d_max = clock.d_max if clock is not None else 0
    h_max = (dl_clock.d_max + 1) if dl_clock is not None else 1
    ccfg = CollabConfig(mode="cors", num_classes=VOCAB, d_feature=d)
    init_pending, round_sync = launch_train.make_async_round_sync(ccfg, d_max)
    pending = init_pending(VOCAB, d)
    hist = relay_history.init(prototypes.init_state(VOCAB, d), h_max)

    writer = (obs.JsonlWriter(args.telemetry_out)
              if args.telemetry_out else None)
    global_state = prototypes.init_state(VOCAB, d)
    late_total = stale_total = 0
    key = jax.random.PRNGKey(42)
    print(f"clients: tinyllama-reduced + xlstm-reduced, vocab={VOCAB}, "
          f"clock={args.clock_model}, download={args.download_clock}")
    print("round  ce[llama]  ce[xlstm]  comm_MB/round")
    for r in range(args.rounds):
        dl = (dl_clock.delays(r, n) if dl_clock is not None
              else np.zeros((n,), np.int64))
        round_stats = []
        ces = []
        for i, (c, corp) in enumerate(zip(clients, splits)):
            # each client trains against the snapshot its download clock
            # last synced — round r - dl[i]'s post-merge prototypes
            proto_means = prototypes.means(
                relay_history.read_at(hist, int(dl[i])))
            key, k1, k2 = jax.random.split(key, 3)
            batches = list(synthetic.lm_batches(
                corp, BATCH, SEQ, STEPS_PER_ROUND,
                seed=int(jax.random.randint(k1, (), 0, 1 << 30))))
            batches = [{k: jnp.asarray(v) for k, v in b.items()}
                       for b in batches]
            ce, stats = local_round(c, batches, proto_means, 1.0, 0.1, k2)
            ces.append(ce)
            round_stats.append(stats)
        # the only exchange: this round's due stats (fresh delay-0 ones
        # plus pending arrivals) merge into a fresh global state, exactly
        # `prototypes.merge(*round_stats)` when the fleet is synchronous
        delays = (clock.delays(r, n) if clock is not None
                  else np.zeros((n,), np.int64))
        stacked = prototypes.ProtoState(
            jnp.stack([s.sum for s in round_stats]),
            jnp.stack([s.count for s in round_stats]))
        state = launch_train.TrainState(
            None, None, prototypes.init_state(VOCAB, d),
            jnp.zeros((), jnp.int32))
        state, pending = round_sync(state, pending,
                                    jnp.asarray(delays, jnp.int32), stacked)
        prev_state, global_state = global_state, state.proto
        hist = relay_history.push(hist, global_state)
        late = int(np.sum(delays > 0))
        stale = int(np.sum(dl > 0))
        late_total += late
        stale_total += stale
        comm_floats = 2 * n * VOCAB * (d + 1)            # up+down, all clients
        comm_mb = comm_floats * 4 / 1e6
        print(f"{r + 1:4d}   {ces[0]:.4f}    {ces[1]:.4f}    {comm_mb:.3f}")
        if writer:
            writer.write({
                "round": r,
                "participants": list(range(n)),
                "ce": {c["cfg"].name: ce for c, ce in zip(clients, ces)},
                "late_commits": late, "stale_reads": stale,
                "comm_up": comm_floats / 2, "comm_down": comm_floats / 2,
                "proto_telemetry": launch_train.proto_round_telemetry(
                    prev_state, global_state),
            })
    if writer:
        writer.close()

    # fleet health — the same counters the collaborative engines surface
    # through repro.obs telemetry, reduced from this loop's own clocks
    if late_total:
        print(f"async prototype relay: {late_total} client-round stat "
              f"uploads committed late (bounded-delay pending, see "
              f"src/repro/launch/train.py)")
    if stale_total:
        print(f"download lag: {stale_total} client-rounds trained against "
              f"a stale prototype snapshot (history ring, see "
              f"src/repro/relay/history.py)")
    if args.telemetry_out:
        print(f"telemetry: {args.telemetry_out} (render with "
              f"`python -m repro.obs.report {args.telemetry_out}`)")
    print("\nheterogeneous-arch collaboration ran end-to-end; the exchanged "
          "state is (V, d'+1) floats per client per round, independent of "
          "either model's size.")


if __name__ == "__main__":
    main()
