"""CoRS across *heterogeneous LM architectures* — the paper's
model-heterogeneity selling point at LM scale: a (reduced) llama-family
client and a (reduced) xLSTM client collaborate purely through per-class
(= per-next-token) feature representations. No weights cross the boundary,
so the architectures never need to match.

  PYTHONPATH=src python examples/collab_lm.py [--rounds R]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import losses, prototypes
from repro.data import synthetic
from repro.models import lm
from repro.optim import adam_init, adam_update

VOCAB = 256
SEQ = 64
BATCH = 8
STEPS_PER_ROUND = 8


def make_client(arch: str, key):
    cfg = get_arch(arch).reduced(vocab_size=VOCAB)
    params = lm.init_lm(key, cfg)
    return {"cfg": cfg, "params": params, "opt": adam_init(params)}


def local_round(client, batches, proto_means, lam_kd, lam_disc, key):
    cfg = client["cfg"]

    def loss_fn(params, batch, k):
        out = lm.forward(params, cfg, {"tokens": batch["tokens"]})
        feats, logits = out["features"], out["logits"]
        labels = batch["labels"]
        l_ce = losses.ce_loss(logits, labels)
        l_kd = losses.kd_loss(feats, proto_means, labels)
        f = feats.reshape(-1, feats.shape[-1])[:64]
        y = labels.reshape(-1)[:64]
        l_disc = losses.disc_loss_sampled(
            k, f, proto_means, y, params["lm_head"], num_negatives=32,
            student_logits=logits.reshape(-1, VOCAB)[:64])
        return l_ce + lam_kd * l_kd + lam_disc * l_disc, (l_ce, feats, labels)

    @jax.jit
    def step(params, opt, batch, k):
        (_, (ce, feats, labels)), g = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, k)
        params, opt = adam_update(params, g, opt)
        return params, opt, ce, feats, labels

    stats = prototypes.init_state(VOCAB, cfg.d_model)
    ce = 0.0
    for i, batch in enumerate(batches):
        key, k = jax.random.split(key)
        client["params"], client["opt"], ce, feats, labels = step(
            client["params"], client["opt"], batch, k)
        stats = prototypes.accumulate(stats, feats.reshape(-1, cfg.d_model),
                                      labels.reshape(-1))
    return float(ce), stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=5)
    args = ap.parse_args()

    keys = jax.random.split(jax.random.PRNGKey(0), 2)
    clients = [make_client("tinyllama-1.1b", keys[0]),
               make_client("xlstm-125m", keys[1])]
    # NOTE: d_model of both reduced archs must match for shared prototypes;
    # reduced() gives 256-dim features for both families here.
    assert clients[0]["cfg"].d_model == clients[1]["cfg"].d_model
    d = clients[0]["cfg"].d_model

    stream = synthetic.token_stream(100_000, vocab=VOCAB, seed=0)
    splits = [stream[:50_000], stream[50_000:]]      # private corpora

    global_state = prototypes.init_state(VOCAB, d)
    key = jax.random.PRNGKey(42)
    print(f"clients: tinyllama-reduced + xlstm-reduced, vocab={VOCAB}")
    print("round  ce[llama]  ce[xlstm]  comm_MB/round")
    for r in range(args.rounds):
        proto_means = prototypes.means(global_state)
        round_stats = []
        ces = []
        for c, corp in zip(clients, splits):
            key, k1, k2 = jax.random.split(key, 3)
            batches = list(synthetic.lm_batches(
                corp, BATCH, SEQ, STEPS_PER_ROUND,
                seed=int(jax.random.randint(k1, (), 0, 1 << 30))))
            batches = [{k: jnp.asarray(v) for k, v in b.items()}
                       for b in batches]
            ce, stats = local_round(c, batches, proto_means, 1.0, 0.1, k2)
            ces.append(ce)
            round_stats.append(stats)
        global_state = prototypes.merge(*round_stats)     # the only exchange
        comm_mb = 2 * 2 * VOCAB * (d + 1) * 4 / 1e6       # up+down, 2 clients
        print(f"{r + 1:4d}   {ces[0]:.4f}    {ces[1]:.4f}    {comm_mb:.3f}")
    print("\nheterogeneous-arch collaboration ran end-to-end; the exchanged "
          "state is (V, d'+1) floats per client per round, independent of "
          "either model's size.")


if __name__ == "__main__":
    main()
