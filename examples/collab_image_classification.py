"""End-to-end driver (paper's own scenario, Table 1 regime): N=5 clients,
sparse local data, CoRS vs IL vs FedAvg over many rounds with eval + exact
communication accounting + checkpointing of every client model.

  PYTHONPATH=src python examples/collab_image_classification.py [--rounds R]
"""
import argparse
import os

import jax

from repro import obs
from repro.checkpoint import save_pytree
from repro.core import client as client_lib, collab, vec_collab
from repro.data import partition, synthetic
from repro.models import cnn, mlp
from repro.types import CollabConfig, FleetConfig, TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--clients", type=int, default=5)
    ap.add_argument("--mode", default="cors",
                    choices=["cors", "il", "fd", "fedavg"])
    ap.add_argument("--lambda-kd", type=float, default=2.0)
    ap.add_argument("--lambda-disc", type=float, default=1.0)
    ap.add_argument("--engine", default="vec", choices=["vec", "seq"],
                    help="vec = one vmapped round step per client bucket "
                         "(default); seq = per-client Python-loop oracle")
    ap.add_argument("--hetero", action="store_true",
                    help="mixed fleet (a CoRS selling point): odd client "
                         "ids run an MLP instead of the LeNet; the vec "
                         "engine buckets them (2 vmapped steps sharing one "
                         "relay), no weights ever cross architectures")
    ap.add_argument("--relay-policy", default="flat",
                    help="server-side relay policy: flat | per_class | "
                         "staleness[:lam] (see src/repro/relay/README.md)")
    ap.add_argument("--participation", default="full",
                    help="per-round client participation schedule: full | "
                         "uniform_k:K | cyclic:K | bernoulli:P | "
                         "adaptive:P[,BOOST] (adaptive boosts observed "
                         "stragglers; e.g. uniform_k:2 = 2 random clients "
                         "per round)")
    ap.add_argument("--clock-model", default="none",
                    help="virtual-time client clock driving the async "
                         "event-ordered relay (repro.sim): none | "
                         "homogeneous[:delay] | lognormal[:dmax[,sigma]] | "
                         "periodic[:dmax[,period]] — e.g. lognormal:4 is a "
                         "straggler fleet whose uploads commit up to 4 "
                         "rounds late, in event order")
    ap.add_argument("--download-clock", default="none",
                    help="download-lag clock (same spec zoo as "
                         "--clock-model, independent randomness): clients "
                         "read teachers and global prototypes from the "
                         "relay snapshot of round t-d via the bounded "
                         "history ring (src/repro/relay/history.py) — "
                         "e.g. periodic:3,4 is a duty-cycled fleet "
                         "training against up-to-3-round-stale syncs")
    ap.add_argument("--telemetry-out", default=None, metavar="RUN.jsonl",
                    help="stream per-round telemetry records to this JSONL "
                         "file (render with `python -m repro.obs.report "
                         "RUN.jsonl`); telemetry metrics are on either "
                         "way — this adds the durable sink")
    ap.add_argument("--out", default="artifacts/collab_ckpt")
    args = ap.parse_args()

    x, y = synthetic.class_images(1200, seed=0, noise=0.8)
    tx, ty = synthetic.class_images(2000, seed=99, noise=0.8)
    parts = partition.uniform_split(x, y, args.clients, seed=1)
    print(f"{args.clients} clients × {len(parts[0][0])} samples each, "
          f"mode={args.mode}, relay={args.relay_policy}, "
          f"participation={args.participation}, clock={args.clock_model}, "
          f"download={args.download_clock}"
          + (", hetero cnn/mlp fleet" if args.hetero else ""))

    cnn_spec = client_lib.ClientSpec(
        apply=lambda p, xx: cnn.apply(p, xx),
        head=lambda p: (p["head_w"], p["head_b"]))
    mlp_spec = client_lib.ClientSpec(
        apply=lambda p, xx: mlp.apply(p, xx),
        head=lambda p: (p["head_w"], p["head_b"]))
    keys = jax.random.split(jax.random.PRNGKey(0), args.clients)
    if args.hetero:
        specs = [cnn_spec if i % 2 == 0 else mlp_spec
                 for i in range(args.clients)]
        params = [cnn.init_cnn(k) if i % 2 == 0 else mlp.init_mlp(k)
                  for i, k in enumerate(keys)]
    else:
        specs = [cnn_spec] * args.clients
        params = [cnn.init_cnn(k) for k in keys]
    ccfg = CollabConfig(mode=args.mode, num_classes=10, d_feature=84,
                        lambda_kd=args.lambda_kd,
                        lambda_disc=args.lambda_disc)
    cls = (vec_collab.VectorizedCollabTrainer if args.engine == "vec"
           else collab.CollabTrainer)
    trainer = cls(specs, params, parts,
                  (tx, ty), ccfg, TrainConfig(batch_size=32), seed=0,
                  telemetry=obs.TelemetryConfig(jsonl=args.telemetry_out),
                  fleet=FleetConfig(policy=args.relay_policy,
                                    participation=args.participation,
                                    clock=args.clock_model,
                                    download_clock=args.download_clock))
    trainer.run(args.rounds, log_every=max(1, args.rounds // 15))
    # fleet health from the engine's own telemetry (repro.obs) — the same
    # counters both engines oracle-check, not recomputed driver-side
    telem = [h["telemetry"] for h in trainer.history]
    late = sum(sum(t["commit_hist"][1:]) for t in telem)
    if late:
        print(f"async relay: {late} uploads committed late "
              f"(event-ordered, see src/repro/relay/events.py)")
    stale = sum(t["stale_reads"] for t in telem)
    if stale:
        print(f"download lag: {stale} client-rounds trained against a "
              f"stale relay snapshot (history ring, see "
              f"src/repro/relay/history.py)")
    if args.telemetry_out:
        print(f"telemetry: {args.telemetry_out} (render with "
              f"`python -m repro.obs.report {args.telemetry_out}`)")

    os.makedirs(args.out, exist_ok=True)
    for i in range(args.clients):
        p = (trainer.client_params(i) if args.engine == "vec"
             else trainer.clients[i].params)
        save_pytree(os.path.join(args.out, f"client{i}.npz"), p,
                    step=args.rounds)
    best = max(h["acc_mean"] for h in trainer.history)
    print(f"\nbest mean accuracy: {best:.4f}; "
          f"total comm {trainer.ledger.total_bytes/1e6:.2f} MB; "
          f"checkpoints in {args.out}/")


if __name__ == "__main__":
    main()
