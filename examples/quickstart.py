"""Quickstart: 60 seconds with the CoRS framework on CPU.

Trains two collaborating clients (different random inits, private data
shards) with the paper's objective L_CE + λ_KD·L_KD + λ_disc·L_disc, and
prints per-round accuracy plus the exact communication ledger.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import client as client_lib, collab
from repro.data import partition, synthetic
from repro.models import cnn
from repro.types import CollabConfig, TrainConfig


def main():
    x, y = synthetic.class_images(600, seed=0, noise=0.6)
    tx, ty = synthetic.class_images(800, seed=9, noise=0.6)
    parts = partition.uniform_split(x, y, 2, seed=1)

    spec = client_lib.ClientSpec(
        apply=lambda p, xx: cnn.apply(p, xx),
        head=lambda p: (p["head_w"], p["head_b"]))
    params = [cnn.init_cnn(k)
              for k in jax.random.split(jax.random.PRNGKey(0), 2)]

    ccfg = CollabConfig(mode="cors", num_classes=10, d_feature=84,
                        lambda_kd=2.0, lambda_disc=1.0)
    trainer = collab.CollabTrainer([spec] * 2, params, parts, (tx, ty),
                                   ccfg, TrainConfig(batch_size=32), seed=0)
    print("round  acc_mean  acc_std   L_CE    L_KD    L_disc   MI-bound")
    for _ in range(8):
        rec = trainer.run_round()
        m = rec["metrics"][0]
        print(f"{rec['round']:4d}   {rec['acc_mean']:.4f}   "
              f"{rec['acc_std']:.4f}  {m['ce']:.3f}  {m.get('kd', 0):.4f}  "
              f"{m.get('disc', 0):.3f}  {m.get('mi_bound', 0):+.3f} nats")
    mb = trainer.ledger.total_bytes / 1e6
    print(f"\ntotal communication: {mb:.2f} MB "
          f"(FedAvg would have used "
          f"{cnn.num_params(params[0]) * 2 * 8 * 4 / 1e6:.1f} MB)")


if __name__ == "__main__":
    main()
