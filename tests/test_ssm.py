"""Mamba2 SSD: chunked == sequential recurrence; decode continuity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.nn import ssm

KEY = jax.random.PRNGKey(0)


def _naive_ssd(xh, Bm, Cm, dt, A):
    """Direct per-step recurrence (f32)."""
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    h = np.zeros((Bsz, H, P, N), np.float64)
    ys = []
    xh, Bm, Cm, dt = map(lambda a: np.asarray(a, np.float64),
                         (xh, Bm, Cm, dt))
    A = np.asarray(A, np.float64)
    for t in range(S):
        decay = np.exp(dt[:, t] * A[None, :])               # (B,H)
        h = decay[:, :, None, None] * h + np.einsum(
            "bh,bn,bhp->bhpn", dt[:, t], Bm[:, t], xh[:, t])
        ys.append(np.einsum("bn,bhpn->bhp", Cm[:, t], h))
    return np.stack(ys, 1), h


@pytest.mark.parametrize("Q", [4, 8, 16])
def test_ssd_chunked_matches_naive(Q):
    B, S, H, P, N = 2, 16, 3, 4, 5
    ks = jax.random.split(KEY, 4)
    xh = jax.random.normal(ks[0], (B, S, H, P))
    Bm = jax.random.normal(ks[1], (B, S, N))
    Cm = jax.random.normal(ks[2], (B, S, N))
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
    A = -jnp.exp(jnp.linspace(-1, 0.5, H))
    y, h = ssm.ssd_chunked(xh, Bm, Cm, dt, A, Q)
    y_ref, h_ref = _naive_ssd(xh, Bm, Cm, dt, A)
    np.testing.assert_allclose(y, y_ref, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(h, h_ref, atol=1e-4, rtol=1e-4)


def test_ssd_chunk_size_invariance():
    B, S, H, P, N = 1, 32, 2, 4, 4
    ks = jax.random.split(KEY, 4)
    xh = jax.random.normal(ks[0], (B, S, H, P))
    Bm = jax.random.normal(ks[1], (B, S, N))
    Cm = jax.random.normal(ks[2], (B, S, N))
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
    A = -jnp.ones((H,))
    y8, _ = ssm.ssd_chunked(xh, Bm, Cm, dt, A, 8)
    y32, _ = ssm.ssd_chunked(xh, Bm, Cm, dt, A, 32)
    np.testing.assert_allclose(y8, y32, atol=1e-4, rtol=1e-4)


def test_mamba2_decode_continues_prefill():
    cfg = get_arch("zamba2-1.2b").reduced(num_layers=1, d_model=64)
    cfg = dataclasses.replace(cfg, shared_attn_period=0,
                              block_pattern=("mamba",))
    p = ssm.init_mamba2(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 9, cfg.d_model))
    # full pass over 9 tokens
    y_full = ssm.mamba2_block(p, cfg, x)
    # prefill 8 then decode the 9th
    _, cache = ssm.mamba2_block(p, cfg, x[:, :8], return_cache=True)
    y_dec, _ = ssm.mamba2_decode(p, cfg, x[:, 8:9], cache)
    np.testing.assert_allclose(y_dec[:, 0], y_full[:, 8], atol=1e-3,
                               rtol=1e-3)
