"""MLA: expanded (train/prefill) vs absorbed (decode) consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.nn import mla

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ["deepseek-v2-lite-16b", "minicpm3-4b"])
def test_decode_matches_expanded_last_position(arch):
    cfg = get_arch(arch).reduced(num_layers=1, d_model=128)
    p = mla.init_mla(KEY, cfg, jnp.float32)
    B, S = 2, 10
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S)).astype(jnp.int32)
    y_full, cache = mla.mla_block(p, cfg, x, pos, return_cache=True)
    # absorbed decode with the last token overwriting the last cache slot
    y_dec, _ = mla.mla_decode(p, cfg, x[:, -1:], cache, pos[:, -1:])
    np.testing.assert_allclose(y_dec[:, 0], y_full[:, -1], atol=1e-4,
                               rtol=1e-4)


def test_cache_is_compressed():
    cfg = get_arch("deepseek-v2-lite-16b")
    # latent cache row = kv_lora + rope dims, NOT heads*(nope+v)
    per_tok_latent = cfg.kv_lora_rank + cfg.qk_rope_dim
    per_tok_full = cfg.num_heads * (cfg.qk_nope_dim + cfg.v_head_dim)
    assert per_tok_latent * 6 < per_tok_full


def test_q_lora_path():
    cfg = get_arch("minicpm3-4b").reduced(num_layers=1, d_model=128)
    assert cfg.q_lora_rank > 0
    p = mla.init_mla(KEY, cfg, jnp.float32)
    assert "wq_a" in p and "q_norm" in p
    x = jax.random.normal(KEY, (1, 4, cfg.d_model))
    pos = jnp.arange(4)[None].astype(jnp.int32)
    y = mla.mla_block(p, cfg, x, pos)
    assert y.shape == (1, 4, cfg.d_model)
    assert np.all(np.isfinite(np.asarray(y)))
