"""Fleet telemetry (src/repro/obs): in-jit round metrics, trace spans,
sinks and the report CLI.

The tentpole invariant: with telemetry on, BOTH engines emit a
`RoundTelemetry` every round whose integer leaves (ring occupancy/fill,
owner diversity, staleness and commit-lag histograms, pending depth,
stale reads) agree BIT-FOR-BIT across every relay policy × fleet clocking
(sync, event-ordered upload lag, download lag), because they reduce the
same exactly-matched ring/event bookkeeping; float leaves (prototype
drift, per-bucket loss/grad-norm) match within the engines' usual vmap
tolerance. Plus: telemetry is free when off (no record entry, and the
step still compiles ONCE with it on — a static build flag, not a traced
branch), the JSONL sink + `python -m repro.obs.report` round-trip, and
the Chrome trace the recorder writes is valid trace-event JSON.

The full policy × clocking cross product runs under the `slow` marker;
tier-1 runs a diagonal (same convention as test_download_lag).
"""
import json

import jax
import numpy as np
import pytest

from oracles import assert_telemetry_match, run_matched
from repro import obs
from repro.obs import report
from repro.core import client as client_lib, collab, vec_collab
from repro.data import partition, synthetic
from repro.models import mlp
from repro.types import CollabConfig, FleetConfig, TrainConfig

SPEC = client_lib.ClientSpec(
    apply=lambda p, x: mlp.apply(p, x),
    head=lambda p: (p["head_w"], p["head_b"]))
SPEC_B = client_lib.ClientSpec(
    apply=lambda p, x: mlp.apply(p, x),
    head=lambda p: (p["head_w"], p["head_b"]))

POLICIES = ["flat", "per_class", "staleness"]
# sync, event-ordered upload lag, download lag
CLOCKINGS = [(None, None), ("lognormal:2", None), (None, "lognormal:2")]


def _build(engine, policy="flat", clock=None, dl_clock=None, schedule=None,
           telemetry=True, n_clients=4, seed=0, hetero=False):
    x, y = synthetic.class_images(192, seed=0, noise=0.4)
    tx, ty = synthetic.class_images(96, seed=9, noise=0.4)
    parts = partition.uniform_split(x, y, n_clients, seed=1)
    ccfg = CollabConfig(mode="cors", num_classes=10, d_feature=84,
                        lambda_kd=2.0)
    tcfg = TrainConfig(batch_size=16)
    keys = jax.random.split(jax.random.PRNGKey(seed), n_clients)
    if hetero:
        specs = [SPEC if i % 2 == 0 else SPEC_B for i in range(n_clients)]
        params = [mlp.init_mlp(k, hidden=64 if i % 2 == 0 else 96)
                  for i, k in enumerate(keys)]
    else:
        specs = [SPEC] * n_clients
        params = [mlp.init_mlp(k) for k in keys]
    cls = (collab.CollabTrainer if engine == "seq"
           else vec_collab.VectorizedCollabTrainer)
    return cls(specs, params, parts, (tx, ty), ccfg, tcfg, seed=seed,
               telemetry=telemetry,
               fleet=FleetConfig(policy=policy, participation=schedule,
                                 clock=clock, download_clock=dl_clock))


# ---------------------------------------------------------------------------
# tentpole: telemetry agrees bit-for-bit across engines
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy,clocking", list(zip(POLICIES, CLOCKINGS)))
def test_telemetry_seq_vec_equivalence(policy, clocking):
    """Tier-1 diagonal of the policy × clocking matrix (full cross product
    under -m slow). run_matched pins the telemetry leaves every round."""
    clock, dl = clocking
    run_matched(_build("seq", policy, clock=clock, dl_clock=dl),
                _build("vec", policy, clock=clock, dl_clock=dl))


@pytest.mark.slow
@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("clocking", CLOCKINGS)
def test_telemetry_full_matrix(policy, clocking):
    clock, dl = clocking
    run_matched(_build("seq", policy, clock=clock, dl_clock=dl),
                _build("vec", policy, clock=clock, dl_clock=dl))


def test_telemetry_hetero_async():
    """Two spec-buckets + upload lag: the bucketed engine computes
    telemetry in its own jitted dispatch (no fused round step) and the
    bucket_loss/grad_norm leaves carry one entry per bucket."""
    seq = _build("seq", "staleness", clock="lognormal:2", hetero=True)
    vec = _build("vec", "staleness", clock="lognormal:2", hetero=True)
    run_matched(seq, vec)
    t = vec.history[-1]["telemetry"]
    assert len(t["bucket_loss"]) == 2 == len(t["bucket_grad_norm"])


def test_telemetry_partial_participation():
    """Absent clients are zeroed out of the bucket means and never count
    as stale reads; commit_hist still accounts every commit."""
    seq = _build("seq", "flat", schedule="uniform_k:2",
                 dl_clock="lognormal:2")
    vec = _build("vec", "flat", schedule="uniform_k:2",
                 dl_clock="lognormal:2")
    run_matched(seq, vec)
    for rec in vec.history:
        t = rec["telemetry"]
        assert sum(t["commit_hist"]) == len(rec["commits"])
        assert t["stale_reads"] <= len(rec["participants"])


# ---------------------------------------------------------------------------
# free when off, one compile when on
# ---------------------------------------------------------------------------
def test_telemetry_off_no_record():
    vec = _build("vec", telemetry=None)
    rec = vec.run_round()
    assert "telemetry" not in rec
    seq = _build("seq", telemetry=False)
    assert "telemetry" not in seq.run_round()


def test_telemetry_kwarg_validated():
    with pytest.raises(TypeError):
        _build("vec", telemetry="yes")


@pytest.mark.parametrize("clock", [None, "lognormal:2"])
def test_telemetry_compile_once(clock):
    """The telemetry flag is a STATIC build choice: with it on, the round
    step still traces exactly once across rounds (sync and async)."""
    vec = _build("vec", clock=clock)
    vec.run(3)
    assert vec._round_step._cache_size() == 1


def test_telemetry_sanity_sync():
    """Shape/semantics floor for one engine: sync fleets pend nothing,
    read nothing stale, and commit exactly the present set at lag 0."""
    vec = _build("vec")
    for _ in range(3):
        rec = vec.run_round()
        t = rec["telemetry"]
        assert t["pending_depth"] == 0 and t["stale_reads"] == 0
        assert t["commit_hist"][0] == len(rec["participants"])
        assert sum(t["commit_hist"][1:]) == 0
        assert t["occupancy"] >= sum(1 for _ in rec["commits"])
        assert len(t["fill"]) == 10
        assert len(t["stale_hist"]) == obs.STALE_BINS
        assert np.isfinite(t["proto_drift"])
    json.dumps(rec["telemetry"])  # JSON-safe host types


# ---------------------------------------------------------------------------
# sinks, report, trace
# ---------------------------------------------------------------------------
def test_jsonl_sink_and_report(tmp_path):
    path = tmp_path / "run.jsonl"
    cfg = obs.TelemetryConfig(jsonl=str(path))
    vec = _build("vec", clock="lognormal:2", telemetry=cfg)
    vec.run(3)
    records = obs.read_jsonl(str(path))
    assert len(records) == 3
    assert_telemetry_match(records[-1]["telemetry"],
                           vec.history[-1]["telemetry"])
    out = report.render(records)
    assert "run report: 3 rounds" in out
    assert "commit-lag histogram" in out
    assert "staleness histogram" in out
    assert "comm: up" in out
    # the CLI renders the same file end-to-end
    assert report.main([str(path), "--last", "2"]) == 0


def test_report_degrades_without_telemetry(tmp_path):
    """Sink on, metrics off: the report falls back to accuracy/comm."""
    path = tmp_path / "run.jsonl"
    cfg = obs.TelemetryConfig(metrics=False, jsonl=str(path))
    vec = _build("vec", telemetry=cfg)
    vec.run_round()
    out = report.render(obs.read_jsonl(str(path)))
    assert "run report: 1 rounds" in out
    assert "commit-lag histogram" not in out


def test_trace_chrome_json(tmp_path):
    """The recorder emits valid Chrome trace-event JSON (Perfetto's
    "Open trace file" format): complete "X" events with µs timestamps,
    covering the engine's round phases."""
    path = tmp_path / "trace.json"
    cfg = obs.TelemetryConfig(trace=str(path), profile=True)
    seq = _build("seq", clock="lognormal:2", telemetry=cfg)
    seq.run(2)
    doc = json.loads(path.read_text())
    events = doc["traceEvents"]
    assert events
    names = {e["name"] for e in events}
    assert {"teacher_read", "update", "upload", "commit",
            "eval"} <= names
    for e in events:
        assert e["ph"] == "X" and e["dur"] >= 0 and "ts" in e

    vpath = tmp_path / "vtrace.json"
    vec = _build("vec", telemetry=obs.TelemetryConfig(trace=str(vpath)))
    vec.run(2)
    vnames = {e["name"] for e in json.loads(vpath.read_text())["traceEvents"]}
    assert {"round_step", "eval"} <= vnames
