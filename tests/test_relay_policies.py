"""Relay-policy + participation subsystem (src/repro/relay/).

The tentpole invariant: for EVERY (relay policy × participation schedule ×
mode) combination, the sequential oracle and the vectorized engine evolve
the same relay state (exact ring bookkeeping, obs within float tolerance)
and the same per-round records. Plus policy unit mechanics (per-class rings,
staleness aging/sampling), schedule determinism, and the jit-cache
assertions (one round step per (policy, schedule); compute_uploads traces
once per spec).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from oracles import assert_states_match as _assert_states_match
from repro import relay as relay_lib
from repro.core import client as client_lib, collab, prototypes, vec_collab
from repro.data import partition, synthetic
from repro.models import mlp
from repro.types import CollabConfig, FleetConfig, TrainConfig

SPEC = client_lib.ClientSpec(
    apply=lambda p, x: mlp.apply(p, x),
    head=lambda p: (p["head_w"], p["head_b"]))

POLICIES = ["flat", "per_class", "staleness"]
SCHEDULES = ["full", "uniform_k:2", "bernoulli:0.5"]


def _build(engine, policy, schedule, mode="cors", n_clients=4, n=256,
           seed=0):
    x, y = synthetic.class_images(n, seed=0, noise=0.4)
    tx, ty = synthetic.class_images(128, seed=9, noise=0.4)
    parts = partition.uniform_split(x, y, n_clients, seed=1)
    ccfg = CollabConfig(mode=mode, num_classes=10, d_feature=84,
                        lambda_kd=2.0,
                        lambda_disc=1.0 if mode == "cors" else 0.0)
    tcfg = TrainConfig(batch_size=16)
    params = [mlp.init_mlp(k)
              for k in jax.random.split(jax.random.PRNGKey(seed), n_clients)]
    cls = (collab.CollabTrainer if engine == "seq"
           else vec_collab.VectorizedCollabTrainer)
    return cls([SPEC] * n_clients, params, parts, (tx, ty), ccfg, tcfg,
               seed=seed,
               fleet=FleetConfig(policy=policy, participation=schedule))


# ---------------------------------------------------------------------------
# tentpole: seq/vec equivalence for every (policy × schedule × mode)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("schedule", SCHEDULES)
@pytest.mark.parametrize("mode", ["cors", "fd"])
def test_seq_vec_equivalence(policy, schedule, mode):
    seq = _build("seq", policy, schedule, mode=mode)
    vec = _build("vec", policy, schedule, mode=mode)
    for _ in range(2):
        rs, rv = seq.run_round(), vec.run_round()
        assert rs["participants"] == rv["participants"]
        np.testing.assert_allclose(rs["accs"], rv["accs"], atol=2e-2)
    assert seq.ledger.by_round == vec.ledger.by_round
    assert seq.ledger.total_bytes == vec.ledger.total_bytes
    _assert_states_match(seq.server.state, vec.relay_state)


def test_absent_clients_frozen_and_unbilled():
    """cyclic:1 at N=3: exactly one client moves per round, the others'
    params stay bit-identical, and the ledger bills one client."""
    vec = _build("vec", "flat", "cyclic:1", n_clients=3, n=192)
    before = [jax.tree.map(np.asarray, vec.client_params(i))
              for i in range(3)]
    rec = vec.run_round()
    assert rec["participants"] == [0]
    after = [jax.tree.map(np.asarray, vec.client_params(i))
             for i in range(3)]
    for i in (1, 2):
        jax.tree.map(np.testing.assert_array_equal, before[i], after[i])
    with pytest.raises(AssertionError):
        jax.tree.map(np.testing.assert_array_equal, before[0], after[0])
    ccfg = vec.ccfg
    per_client = (ccfg.m_up + 1) * ccfg.num_classes * ccfg.d_feature
    assert rec["comm_up"] == per_client        # ONE client billed
    # absent clients report zero metrics with the full key set
    assert rec["metrics"][1] == client_lib.zero_metrics(ccfg)


def test_zero_participant_round_is_relay_noop():
    """A bernoulli round where nobody shows up must leave the relay state
    untouched (no merge, no aging) in BOTH engines."""

    class NoShow(relay_lib.ParticipationSchedule):
        name = "noshow"

        def mask(self, round_idx, n_clients):
            return np.zeros((n_clients,), bool)

    for engine in ("seq", "vec"):
        tr = _build(engine, "staleness", NoShow(), n_clients=2, n=128)
        state0 = (tr.server.state if engine == "seq" else tr.relay_state)
        state0 = jax.tree.map(np.asarray, state0)
        rec = tr.run_round()
        assert rec["participants"] == []
        assert rec["comm_up"] == rec["comm_down"] == 0.0
        state1 = (tr.server.state if engine == "seq" else tr.relay_state)
        jax.tree.map(np.testing.assert_array_equal, state0,
                     jax.tree.map(np.asarray, state1))


# ---------------------------------------------------------------------------
# one compiled round step per (policy, schedule); jitted uploads per spec
# ---------------------------------------------------------------------------
def test_vec_round_step_compiles_once():
    """Partial participation must not retrace: the mask and gather indices
    are traced args of fixed shape, so 3 rounds = 1 compile."""
    vec = _build("vec", "per_class", "uniform_k:2", n_clients=4, n=192)
    for _ in range(3):
        vec.run_round()
    assert vec._round_step._cache_size() == 1


def test_seq_compute_uploads_jitted_once_per_spec():
    """Satellite (ROADMAP): the sequential oracle's upload computation runs
    jitted, traced once per ClientSpec — not re-traced per round or per
    client (it was eager before: ~20 ms dispatch per client per round)."""
    seq = _build("seq", "flat", "full", n_clients=3, n=192)
    for _ in range(3):
        seq.run_round()
    assert len(seq._upload_cache) == 1          # all clients share SPEC
    fn = seq._upload_cache[SPEC]
    assert fn._cache_size() == 1                # one trace, ever
    seq.run_round()
    assert seq._upload_cache[SPEC] is fn
    assert fn._cache_size() == 1


# ---------------------------------------------------------------------------
# per-class ring mechanics
# ---------------------------------------------------------------------------
def _pc_state(cap=4, C=3, d=2, m_down=1):
    ccfg = CollabConfig(num_classes=C, d_feature=d, m_down=m_down)
    return relay_lib.PerClassRelay().init_state(ccfg, d, capacity=cap)


def test_per_class_append_routes_rows_to_class_rings():
    pol = relay_lib.PerClassRelay()
    st = _pc_state(cap=4)
    assert np.asarray(st.ptr).tolist() == [1, 1, 1]     # one seed per class
    valid = jnp.asarray([[True, False, True],
                         [True, True, False]])
    st = pol.append(st, jnp.ones((2, 3, 2)), valid,
                    jnp.asarray([7, 8], jnp.int32))
    # class 0 got both rows, class 1 only row 1, class 2 only row 0
    np.testing.assert_array_equal(np.asarray(st.ptr), [3, 2, 2])
    owner = np.asarray(st.owner)
    assert owner[0, 1] == 7 and owner[0, 2] == 8
    assert owner[1, 1] == 8 and owner[2, 1] == 7
    # untouched slots keep their seed/empty sentinels
    assert owner[1, 2] == relay_lib.EMPTY_OWNER
    assert owner[0, 0] == relay_lib.SEED_OWNER


def test_per_class_sampling_excludes_own_and_respects_class_pools():
    pol = relay_lib.PerClassRelay()
    st = _pc_state(cap=4)
    # class 0: only client 0's row; class 1: clients 0 and 1; class 2: empty
    st = st._replace(
        obs=jnp.zeros((3, 4, 2)).at[1, 1].set(5.0),
        valid=jnp.asarray([[True, False, False, False],
                           [True, True, False, False],
                           [False, False, False, False]]),
        owner=jnp.asarray([[0, -2, -2, -2],
                           [0, 1, -2, -2],
                           [-2, -2, -2, -2]], jnp.int32))
    for s in range(6):
        t = pol.sample_teacher(st, 0, 2, jax.random.PRNGKey(s))
        # class 1 must come from client 1 (value 5), never client 0's zeros
        np.testing.assert_allclose(np.asarray(t["obs"][:, 1]), 5.0)
        # class 0 falls back to the requester's own slot (pool exhausted)
        assert bool(t["valid_o"][0])
        # class 2 ring is empty -> invalid, zero obs
        assert not bool(t["valid_o"][2])
        np.testing.assert_allclose(np.asarray(t["obs"][:, 2]), 0.0)


def test_per_class_merge_ages_valid_slots_only():
    pol = relay_lib.PerClassRelay()
    st = _pc_state(cap=3)
    proto = prototypes.init_state(3, 2)
    st = pol.merge_round(st, prototypes.ProtoState(
        proto.sum + 1.0, proto.count + 1.0))
    age = np.asarray(st.age)
    valid = np.asarray(st.valid)
    assert (age[valid] == 1).all() and (age[~valid] == 0).all()


# ---------------------------------------------------------------------------
# staleness mechanics
# ---------------------------------------------------------------------------
def _stale_state(cap=6, C=3, d=2, lam=1.0):
    ccfg = CollabConfig(num_classes=C, d_feature=d, m_down=1)
    pol = relay_lib.StalenessRelay(lam=lam)
    return pol, pol.init_state(ccfg, d, capacity=cap)


def test_staleness_age_lifecycle():
    """Slots age by 1 per merge; overwriting a slot resets it to 0."""
    pol, st = _stale_state(cap=3)
    proto = prototypes.ProtoState(jnp.ones((3, 2)), jnp.ones((3,)))
    st = pol.append(st, jnp.ones((1, 3, 2)), jnp.ones((1, 3), bool),
                    jnp.asarray([0], jnp.int32))
    st = pol.merge_round(st, proto)
    st = pol.merge_round(st, proto)
    np.testing.assert_array_equal(np.asarray(st.age), [2, 2, 0])
    st = pol.append(st, jnp.full((1, 3, 2), 9.0), jnp.ones((1, 3), bool),
                    jnp.asarray([1], jnp.int32))   # overwrites slot 2
    np.testing.assert_array_equal(np.asarray(st.age), [2, 2, 0])
    st = pol.merge_round(st, proto)
    np.testing.assert_array_equal(np.asarray(st.age), [3, 3, 1])


def test_staleness_sampling_prefers_fresh_slots():
    """With large λ, old slots are (almost) never sampled: fill slots with
    their age as the value and check the sampled teacher is fresh."""
    pol, st = _stale_state(cap=6, lam=8.0)
    st = st._replace(
        obs=jnp.arange(6, dtype=jnp.float32)[:, None, None]
        * jnp.ones((6, 3, 2)),
        valid=jnp.ones((6, 3), bool),
        owner=jnp.asarray([1, 1, 1, 1, 1, 1], jnp.int32),
        age=jnp.asarray([0, 5, 5, 5, 5, 5], jnp.int32))
    picks = [float(np.asarray(
        pol.sample_teacher(st, 0, 1, jax.random.PRNGKey(s))["obs"]).max())
        for s in range(40)]
    assert np.mean([p == 0.0 for p in picks]) > 0.9


def test_staleness_tolerates_m_down_beyond_pool_and_capacity():
    """Flat-policy parity contract: any m_down works. m_down > capacity
    must not crash (top_k k is clamped), and a pool smaller than m_down
    recycles in-pool picks instead of invalidating the teacher."""
    pol, st = _stale_state(cap=4, lam=1.0)
    # pool for client 0 = client 1's two slots; m_down = 8 > cap = 4
    st = st._replace(valid=jnp.ones((4, 3), bool),
                     owner=jnp.asarray([0, 0, 1, 1], jnp.int32),
                     obs=jnp.arange(4, dtype=jnp.float32)[:, None, None]
                     * jnp.ones((4, 3, 2)))
    t = pol.sample_teacher(st, 0, 8, jax.random.PRNGKey(0))
    assert t["obs"].shape == (8, 3, 2)
    assert bool(jnp.all(t["valid_o"]))           # NOT poisoned
    vals = set(np.asarray(t["obs"]).reshape(8, -1)[:, 0].tolist())
    assert vals <= {2.0, 3.0}                    # only client 1's slots


def test_staleness_lam_zero_is_uniform_over_pool():
    """λ=0 degenerates to uniform-without-replacement over others' slots."""
    pol, st = _stale_state(cap=4, lam=0.0)
    st = st._replace(valid=jnp.ones((4, 3), bool),
                     owner=jnp.asarray([0, 1, 1, 1], jnp.int32),
                     age=jnp.asarray([0, 0, 50, 100], jnp.int32),
                     obs=jnp.arange(4, dtype=jnp.float32)[:, None, None]
                     * jnp.ones((4, 3, 2)))
    seen = set()
    for s in range(60):
        t = pol.sample_teacher(st, 0, 1, jax.random.PRNGKey(s))
        v = float(np.asarray(t["obs"]).max())
        assert v != 0.0                          # never the requester's own
        seen.add(v)
    assert seen == {1.0, 2.0, 3.0}               # all ages reachable


# ---------------------------------------------------------------------------
# participation schedules
# ---------------------------------------------------------------------------
def test_schedules_are_deterministic_and_sized():
    for spec in ("full", "uniform_k:3", "cyclic:3", "bernoulli:0.4"):
        a = relay_lib.get_schedule(spec, seed=5)
        b = relay_lib.get_schedule(spec, seed=5)
        for r in range(6):
            np.testing.assert_array_equal(a.mask(r, 8), b.mask(r, 8))
    uk = relay_lib.get_schedule("uniform_k:3", seed=1)
    assert all(uk.mask(r, 8).sum() == 3 for r in range(10))
    assert uk.fixed_k == 3


def test_cyclic_covers_all_clients():
    cy = relay_lib.get_schedule("cyclic:3")
    hit = np.zeros(8, bool)
    for r in range(3):                           # ceil(8/3) = 3 rounds
        hit |= cy.mask(r, 8)
    assert hit.all()


def test_get_policy_and_schedule_specs():
    assert isinstance(relay_lib.get_policy(None), relay_lib.FlatRelay)
    assert relay_lib.get_policy("staleness:0.25").lam == 0.25
    p = relay_lib.PerClassRelay()
    assert relay_lib.get_policy(p) is p
    with pytest.raises(ValueError):
        relay_lib.get_policy("nope")
    with pytest.raises(ValueError):
        relay_lib.get_schedule("nope:3")
    assert isinstance(relay_lib.get_schedule(None),
                      relay_lib.FullParticipation)
