"""Optimizer / data pipeline / checkpoint substrates."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_pytree, save_pytree
from repro.data import partition, synthetic
from repro.optim import (adam_init, adam_update, clip_by_global_norm,
                         sgd_init, sgd_update)

KEY = jax.random.PRNGKey(0)


def test_adam_converges_quadratic():
    p = {"w": jnp.array([3.0, -2.0])}
    opt = adam_init(p)
    loss = lambda pp: jnp.sum(pp["w"] ** 2)
    for _ in range(300):
        g = jax.grad(loss)(p)
        p, opt = adam_update(p, g, opt, lr=0.05)
    assert float(loss(p)) < 1e-4


def test_adam_bias_correction_first_step():
    p = {"w": jnp.array([1.0])}
    opt = adam_init(p)
    g = {"w": jnp.array([0.5])}
    p2, _ = adam_update(p, g, opt, lr=0.1)
    # first Adam step ≈ lr * sign(g)
    np.testing.assert_allclose(float(p2["w"][0]), 1.0 - 0.1, atol=1e-4)


def test_sgd_momentum():
    p = {"w": jnp.array([1.0])}
    opt = sgd_init(p, momentum=0.9)
    g = {"w": jnp.array([1.0])}
    p, opt = sgd_update(p, g, opt, lr=0.1, momentum=0.9)
    p, opt = sgd_update(p, g, opt, lr=0.1, momentum=0.9)
    np.testing.assert_allclose(float(p["w"][0]), 1.0 - 0.1 - 0.19, atol=1e-6)


def test_clip_by_global_norm():
    g = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
    clipped, gn = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(gn), 5.0)
    total = np.sqrt(sum(float(jnp.sum(x ** 2))
                        for x in jax.tree.leaves(clipped)))
    np.testing.assert_allclose(total, 1.0, rtol=1e-5)


def test_uniform_split_partitions_everything():
    x, y = synthetic.class_images(101, seed=0)
    parts = partition.uniform_split(x, y, 4, seed=0)
    assert sum(len(p[0]) for p in parts) == 101


def test_dirichlet_split_skews_labels():
    x, y = synthetic.class_images(2000, seed=0)
    parts = partition.dirichlet_split(x, y, 4, alpha=0.1, seed=0)
    assert sum(len(p[0]) for p in parts) == len(x)
    # low alpha -> at least one client has a dominant class
    fracs = []
    for px, py in parts:
        if len(py):
            fracs.append(np.bincount(py, minlength=10).max() / len(py))
    assert max(fracs) > 0.3


def test_token_stream_learnable_structure():
    t = synthetic.token_stream(5000, vocab=64, seed=0)
    assert t.min() >= 0 and t.max() < 64
    t2 = synthetic.token_stream(5000, vocab=64, seed=0)
    np.testing.assert_array_equal(t, t2)   # deterministic


def test_lm_batches_shapes():
    t = synthetic.token_stream(4000, vocab=32, seed=0)
    batches = list(synthetic.lm_batches(t, batch=4, seq=16, steps=3))
    assert len(batches) == 3
    for b in batches:
        assert b["tokens"].shape == (4, 16)
        np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": {"b": jnp.arange(6).reshape(2, 3).astype(jnp.float32)},
            "c": [jnp.ones((4,)), jnp.zeros((2, 2), jnp.int32)]}
    path = os.path.join(tmp_path, "ck.npz")
    save_pytree(path, tree, step=7)
    template = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)
    back = load_pytree(path, template)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_mid_string_npz_in_path(tmp_path):
    """Only a TRAILING .npz is the extension: a run directory named e.g.
    `sweep.npz_v2` must not be truncated into a sibling path."""
    import pytest
    run_dir = os.path.join(tmp_path, "sweep.npz_v2")
    path = os.path.join(run_dir, "ck.npz")
    tree = {"w": jnp.arange(4.0)}
    save_pytree(path, tree, step=3)
    assert os.path.exists(os.path.join(run_dir, "ck.npz"))
    assert os.path.exists(os.path.join(run_dir, "ck.json"))
    back = load_pytree(path, jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree))
    np.testing.assert_array_equal(np.asarray(back["w"]),
                                  np.asarray(tree["w"]))
    # extensionless paths gain the suffix instead of losing characters
    save_pytree(os.path.join(run_dir, "plain"), tree)
    assert os.path.exists(os.path.join(run_dir, "plain.npz"))
    with pytest.raises(KeyError):
        # template structure must match what was stored
        load_pytree(path, {"missing": jax.ShapeDtypeStruct((4,),
                                                           jnp.float32)})


def test_checkpoint_shape_mismatch_raises(tmp_path):
    import pytest
    path = os.path.join(tmp_path, "ck.npz")
    save_pytree(path, {"w": jnp.zeros((2, 3))})
    bad = {"w": jax.ShapeDtypeStruct((3, 2), jnp.float32)}
    with pytest.raises(ValueError, match="does not match template shape"):
        load_pytree(path, bad)
