"""Sharding rules + roofline HLO parsing (pure-python units)."""
import pytest
from jax.sharding import PartitionSpec as P

from repro import sharding
from repro.launch import roofline
from repro.configs import get_arch
from repro.types import ShapeConfig


def test_param_spec_vocab_over_model():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    spec = sharding.param_spec("embed", (65024, 4096), FakeMesh(), fsdp=False)
    assert spec == P("model", None)
    # size-1 model axis -> no sharding
    class OneMesh:
        axis_names = ("data", "model")
        shape = {"data": 1, "model": 1}
    assert sharding.param_spec("embed", (65024, 4096), OneMesh(),
                               fsdp=False) == P(None, None)


def test_param_spec_non_divisible_replicates():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    spec = sharding.param_spec("wq", (100, 37), FakeMesh(), fsdp=False)
    assert spec == P(None, None)            # 37 % 16 != 0


def test_param_spec_fsdp_adds_data_axis():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    spec = sharding.param_spec("w_up", (8192, 22016), FakeMesh(), fsdp=True)
    assert spec == P("data", "model")


def test_head_axis_plan():
    assert sharding.head_axis_plan(32, 128, 16) == "heads"
    assert sharding.head_axis_plan(28, 128, 16) == "head_dim"
    assert sharding.head_axis_plan(28, 100, 16) == "none"
    assert sharding.head_axis_plan(28, 100, 1) == "none"


HLO = """
ENTRY %main {
  %p0 = bf16[128,1024]{1,0} parameter(0)
  %ar = bf16[128,1024]{1,0} all-reduce(bf16[128,1024]{1,0} %p0), replica_groups={}
  %ag = f32[256,64]{1,0} all-gather(f32[16,64]{1,0} %p0b), dimensions={0}
  %rs = f32[2,4]{1,0} reduce-scatter(f32[32,4]{1,0} %x), dimensions={0}
  %cp = u32[8]{0} collective-permute(u32[8]{0} %y), source_target_pairs={{0,1}}
  %a2a = bf16[4,4]{1,0} all-to-all(bf16[4,4]{1,0} %z), dimensions={0}
  %not = f32[9]{0} add(f32[9]{0} %a, f32[9]{0} %b)
}
"""


def test_collective_bytes_parser():
    out = roofline.collective_bytes(HLO)
    assert out["all-reduce"] == 128 * 1024 * 2
    assert out["all-gather"] == 16 * 64 * 4
    assert out["reduce-scatter"] == 32 * 4 * 4
    assert out["collective-permute"] == 8 * 4
    assert out["all-to-all"] == 4 * 4 * 2
    assert out["count"] == 5
    assert out["total"] == sum(out[k] for k in
                               ("all-reduce", "all-gather", "reduce-scatter",
                                "all-to-all", "collective-permute"))


def test_terms_bottleneck_identification():
    t = roofline.terms({"flops": 197e12, "bytes accessed": 1.0},
                       {"total": 0})
    assert t["bottleneck"] == "compute"
    t = roofline.terms({"flops": 1.0, "bytes accessed": 819e9 * 2},
                       {"total": 0})
    assert t["bottleneck"] == "memory"
    t = roofline.terms({"flops": 0.0, "bytes accessed": 0.0},
                       {"total": 50e9 * 3})
    assert t["bottleneck"] == "collective"


def test_model_flops_train_vs_decode():
    cfg = get_arch("tinyllama-1.1b")
    train = ShapeConfig("t", 4096, 256, "train")
    dec = ShapeConfig("d", 32768, 128, "decode")
    ft = roofline.model_flops(cfg, train)
    fd = roofline.model_flops(cfg, dec)
    # train: 6*N*B*S; decode: 2*N*B
    assert ft / fd == pytest.approx(3 * 4096 * 256 / 128, rel=1e-6)


def test_active_params_close_to_nominal():
    # tinyllama ~1.1B
    n = roofline.active_params(get_arch("tinyllama-1.1b"))
    assert 0.9e9 < n < 1.3e9
    # deepseek-67b
    n = roofline.active_params(get_arch("deepseek-67b"))
    assert 60e9 < n < 72e9
    # granite MoE active ~400M << total
    n = roofline.active_params(get_arch("granite-moe-1b-a400m"))
    assert n < 0.8e9


def test_depth_variants_counts():
    cfg = get_arch("zamba2-1.2b")
    cfgs, counts, names = roofline.depth_variants(cfg)
    assert set(names) == {"mamba", "shared"}
    rc = roofline.real_counts(cfg)
    assert rc["mamba"] == 38 and rc["shared"] == 6
    cfg = get_arch("whisper-small")
    _, _, names = roofline.depth_variants(cfg)
    assert set(names) == {"enc", "dec"}
    rc = roofline.real_counts(cfg)
    assert rc == {"enc": 12, "dec": 12}
