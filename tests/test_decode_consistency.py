"""Prefill→decode equals full forward at the appended position, per arch
family (the strongest correctness check for the serving path)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import encdec, lm

KEY = jax.random.PRNGKey(0)
S = 12

# attention-cache archs: decode appends via the padded-slot trick
APPEND_ARCHS = ["tinyllama-1.1b", "chatglm3-6b", "deepseek-v2-lite-16b",
                "minicpm3-4b", "granite-moe-1b-a400m"]
# pure-state archs: caches are recurrent states, append is native
STATE_ARCHS = ["xlstm-125m"]


@pytest.mark.parametrize("arch", APPEND_ARCHS)
def test_decode_appends_exactly_attention(arch):
    cfg = get_arch(arch).reduced()
    if cfg.num_experts:
        # capacity effects differ between S-1 and S token dispatch: relax by
        # using ample capacity so routing is identical
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.num_experts))
    p = lm.init_lm(KEY, cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, S), 0,
                              cfg.vocab_size)
    full = lm.forward(p, cfg, {"tokens": toks}, mode="train")
    pre = lm.forward(p, cfg, {"tokens": toks[:, :S - 1]}, mode="prefill")
    padded = lm.pad_cache_for_decode(cfg, pre["caches"])
    dec = lm.decode_step(p, cfg, {"tokens": toks[:, S - 1:]}, padded)
    np.testing.assert_allclose(np.asarray(dec["logits"][:, 0]),
                               np.asarray(full["logits"][:, -1]),
                               atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("arch", STATE_ARCHS)
def test_decode_appends_exactly_state(arch):
    cfg = get_arch(arch).reduced()
    p = lm.init_lm(KEY, cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, S), 0,
                              cfg.vocab_size)
    full = lm.forward(p, cfg, {"tokens": toks}, mode="train")
    pre = lm.forward(p, cfg, {"tokens": toks[:, :S - 1]}, mode="prefill")
    dec = lm.decode_step(p, cfg, {"tokens": toks[:, S - 1:]}, pre["caches"])
    np.testing.assert_allclose(np.asarray(dec["logits"][:, 0]),
                               np.asarray(full["logits"][:, -1]),
                               atol=2e-3, rtol=2e-3)


def test_decode_appends_exactly_mamba_only_zamba():
    cfg = get_arch("zamba2-1.2b").reduced()
    cfg = dataclasses.replace(cfg, shared_attn_period=0)   # pure-state path
    p = lm.init_lm(KEY, cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, S), 0,
                              cfg.vocab_size)
    full = lm.forward(p, cfg, {"tokens": toks}, mode="train")
    pre = lm.forward(p, cfg, {"tokens": toks[:, :S - 1]}, mode="prefill")
    dec = lm.decode_step(p, cfg, {"tokens": toks[:, S - 1:]}, pre["caches"])
    np.testing.assert_allclose(np.asarray(dec["logits"][:, 0]),
                               np.asarray(full["logits"][:, -1]),
                               atol=2e-3, rtol=2e-3)


def test_decode_whisper_appends():
    cfg = get_arch("whisper-small").reduced()
    p = encdec.init_encdec(KEY, cfg)
    frames = jax.random.normal(jax.random.PRNGKey(2),
                               (2, cfg.encoder_seq, cfg.d_model))
    enc = encdec.encode(p, cfg, frames)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, S), 0,
                              cfg.vocab_size)
    full = encdec.decode_forward(p, cfg, toks, enc, mode="train")
    pre = encdec.decode_forward(p, cfg, toks[:, :S - 1], enc, mode="prefill")
    self_c = jax.tree.map(
        lambda a: jnp.pad(a, [(0, 0), (0, 0), (0, 1), (0, 0), (0, 0)]),
        pre["caches"]["self"])
    dec = encdec.decode_forward(p, cfg, toks[:, S - 1:], None, mode="decode",
                                self_cache=self_c,
                                cross_kv=pre["caches"]["cross"])
    np.testing.assert_allclose(np.asarray(dec["logits"][:, 0]),
                               np.asarray(full["logits"][:, -1]),
                               atol=2e-3, rtol=2e-3)


def test_vlm_embeddings_decode():
    cfg = get_arch("qwen2-vl-7b").reduced()
    p = lm.init_lm(KEY, cfg)
    emb = jax.random.normal(jax.random.PRNGKey(3), (2, S, cfg.d_model))
    from repro.nn import rope
    pos = rope.default_positions(2, S, "mrope")
    full = lm.forward(p, cfg, {"embeddings": emb, "positions": pos},
                      mode="train")
    pre = lm.forward(p, cfg, {"embeddings": emb[:, :S - 1],
                              "positions": pos[:, :S - 1]}, mode="prefill")
    padded = lm.pad_cache_for_decode(cfg, pre["caches"])
    dec = lm.decode_step(p, cfg, {"embeddings": emb[:, S - 1:],
                                  "positions": pos[:, S - 1:]}, padded)
    np.testing.assert_allclose(np.asarray(dec["logits"][:, 0]),
                               np.asarray(full["logits"][:, -1]),
                               atol=2e-3, rtol=2e-3)


def test_masked_incremental_decode_matches_forward():
    """Serving path: fixed-size cache + cache_index + validity masking
    generates the same logits as teacher-forced full forwards."""
    cfg = get_arch("tinyllama-1.1b").reduced()
    p = lm.init_lm(KEY, cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              cfg.vocab_size)
    # prefill 4, then feed tokens 4..7 one at a time into a size-8 cache
    pre = lm.forward(p, cfg, {"tokens": toks[:, :4]}, mode="prefill")
    caches = jax.tree.map(
        lambda a: jnp.pad(a, [(0, 0), (0, 0), (0, 4)] + [(0, 0)] *
                          (a.ndim - 3)), pre["caches"]["segments"][0])
    caches = {"segments": [caches], "shared": []}
    outs = []
    for i in range(4, 8):
        o = lm.decode_step(p, cfg, {"tokens": toks[:, i:i + 1]}, caches,
                           cache_index=jnp.asarray(i, jnp.int32),
                           masked=True)
        caches = o["caches"]
        outs.append(o["logits"][:, 0])
    full = lm.forward(p, cfg, {"tokens": toks}, mode="train")
    for i, got in enumerate(outs):
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(full["logits"][:, 4 + i]),
                                   atol=2e-3, rtol=2e-3)
