"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import disc_loss as dl
from repro.kernels import flash_attention as fa
from repro.kernels import proto_accum as pa
from repro.kernels import ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("B,Sq,Sk,H,G,hd", [
    (2, 128, 128, 4, 2, 64),
    (1, 256, 256, 8, 8, 128),
    (2, 128, 128, 4, 1, 32),
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, Sq, Sk, H, G, hd, causal, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, Sk, G, hd), dtype)
    v = jax.random.normal(ks[2], (B, Sk, G, hd), dtype)
    out = fa.flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                             interpret=True)
    want = ref.flash_attention(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(out.astype(jnp.float32),
                               want.astype(jnp.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("n,d,C", [(100, 84, 10), (512, 128, 256),
                                   (1000, 64, 300), (7, 16, 4)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_proto_accum(n, d, C, dtype):
    ks = jax.random.split(KEY, 2)
    f = jax.random.normal(ks[0], (n, d), dtype)
    l = jax.random.randint(ks[1], (n,), 0, C)
    s, c = pa.proto_accum(f, l, C, block_n=128, block_c=64, interpret=True)
    rs, rc = ref.proto_accum(f, l, C)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(s, rs, atol=tol, rtol=tol)
    np.testing.assert_allclose(c, rc, atol=1e-6)


@pytest.mark.parametrize("B,C,M", [(32, 10, 10), (64, 1000, 10),
                                   (100, 777, 33), (256, 2048, 128)])
def test_disc_loss(B, C, M):
    ks = jax.random.split(KEY, 3)
    s_log = jax.random.normal(ks[0], (B, C)) * 2
    q = jax.nn.softmax(jax.random.normal(ks[1], (M, C)) * 2, axis=-1)
    y = jax.random.randint(ks[2], (B,), 0, M)
    out = dl.disc_loss(s_log, q, y, jnp.ones((M,), bool), block_b=32,
                       block_c=256, interpret=True)
    want = ref.disc_loss(s_log, q, y, None)
    np.testing.assert_allclose(out, want, atol=2e-4, rtol=2e-4)


def test_disc_loss_valid_mask():
    ks = jax.random.split(KEY, 3)
    B, C, M = 16, 64, 8
    s_log = jax.random.normal(ks[0], (B, C))
    q = jax.nn.softmax(jax.random.normal(ks[1], (M, C)), axis=-1)
    y = jax.random.randint(ks[2], (B,), 0, M)
    valid = (jnp.arange(M) % 2 == 0)
    out = dl.disc_loss(s_log, q, y, valid, block_b=16, block_c=64,
                       interpret=True)
    want = ref.disc_loss(s_log, q, y, valid)
    np.testing.assert_allclose(out, want, atol=2e-4, rtol=2e-4)


def test_ref_disc_equals_core_loss():
    """ref.disc_loss (per-sample) must agree with core.losses.disc_loss
    (mean over valid samples) for full-validity inputs."""
    from repro.core import losses
    ks = jax.random.split(KEY, 3)
    B, C, d = 12, 10, 8
    feats = jax.random.normal(ks[0], (B, d))
    obs = jax.random.normal(ks[1], (C, d))
    y = jax.random.randint(ks[2], (B,), 0, C)
    w = jax.random.normal(jax.random.PRNGKey(9), (d, C))
    core = float(losses.disc_loss(feats, obs, y, w))
    q = jax.nn.softmax(obs @ w, axis=-1)
    per = ref.disc_loss(feats @ w, q, y)
    np.testing.assert_allclose(core, float(per.mean()), rtol=1e-5)


def test_ops_wrappers_dispatch():
    from repro.kernels import ops
    q = jax.random.normal(KEY, (1, 128, 4, 32))
    k = jax.random.normal(KEY, (1, 128, 2, 32))
    v = jax.random.normal(KEY, (1, 128, 2, 32))
    a = ops.flash_attention(q, k, v, causal=True)               # ref on CPU
    b = ops.flash_attention(q, k, v, causal=True, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
