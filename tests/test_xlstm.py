"""xLSTM: mLSTM chunked parallel form vs sequential; sLSTM scan; decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.nn import xlstm

KEY = jax.random.PRNGKey(0)


def _naive_mlstm(q, k, v, logf, logi):
    B, S, H, P = q.shape
    q = np.asarray(q, np.float64) * P ** -0.5
    k, v = np.asarray(k, np.float64), np.asarray(v, np.float64)
    f = np.exp(np.asarray(logf, np.float64))
    i = np.exp(np.asarray(logi, np.float64))
    C = np.zeros((B, H, P, P))
    n = np.zeros((B, H, P))
    ys = []
    for t in range(S):
        C = f[:, t, :, None, None] * C + i[:, t, :, None, None] * np.einsum(
            "bhp,bhn->bhpn", v[:, t], k[:, t])
        n = f[:, t, :, None] * n + i[:, t, :, None] * k[:, t]
        num = np.einsum("bhn,bhpn->bhp", q[:, t], C)
        den = np.abs(np.einsum("bhn,bhn->bh", q[:, t], n))
        ys.append(num / np.maximum(den, 1.0)[:, :, None])
    return np.stack(ys, 1)


@pytest.mark.parametrize("Q", [4, 16])
def test_mlstm_chunked_matches_naive(Q):
    B, S, H, P = 2, 16, 2, 4
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, S, H, P))
    k = jax.random.normal(ks[1], (B, S, H, P))
    v = jax.random.normal(ks[2], (B, S, H, P))
    logf = jax.nn.log_sigmoid(jax.random.normal(ks[3], (B, S, H)))
    logi = jax.random.normal(ks[4], (B, S, H)) * 0.3
    y, _ = xlstm.mlstm_chunked(q, k, v, logf, logi, Q)
    want = _naive_mlstm(q, k, v, logf, logi)
    np.testing.assert_allclose(y, want, atol=1e-4, rtol=1e-4)


def test_mlstm_block_decode_continues_prefill():
    cfg = get_arch("xlstm-125m").reduced(num_layers=1, d_model=64)
    p = xlstm.init_mlstm(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 9, cfg.d_model))
    y_full = xlstm.mlstm_block(p, cfg, x)
    _, cache = xlstm.mlstm_block(p, cfg, x[:, :8], return_cache=True)
    y_dec, _ = xlstm.mlstm_block(p, cfg, x[:, 8:9], cache=cache, decode=True)
    np.testing.assert_allclose(y_dec[:, 0], y_full[:, 8], atol=1e-3,
                               rtol=1e-3)


def test_slstm_normalizer_bounds_state():
    cfg = get_arch("xlstm-125m").reduced(num_layers=1, d_model=64)
    p = xlstm.init_slstm(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model)) * 3
    y = xlstm.slstm_block(p, cfg, x)
    assert np.all(np.isfinite(np.asarray(y)))


def test_slstm_decode_continues_prefill():
    cfg = get_arch("xlstm-125m").reduced(num_layers=1, d_model=64)
    p = xlstm.init_slstm(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 9, cfg.d_model))
    y_full = xlstm.slstm_block(p, cfg, x)
    _, state = xlstm.slstm_block(p, cfg, x[:, :8], return_cache=True)
    y_dec, _ = xlstm.slstm_block(p, cfg, x[:, 8:9], cache=state, decode=True)
    np.testing.assert_allclose(y_dec[:, 0], y_full[:, 8], atol=1e-4,
                               rtol=1e-4)
