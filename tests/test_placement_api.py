"""Placement API surface (src/repro/relay/placement.py) + the FleetConfig
and re-export deprecation shims.

Pins the contracts the placement redesign introduced: every relay-side
state kind declares its placement (`out_spec`), `resolve` turns those
declarations into NamedShardings, `exchange` is a no-op off-mesh, the
sequential oracle rejects a mesh with an error that says why, and the
legacy trainer kwargs warn — tier-1 runs with `repro:`-prefixed
DeprecationWarnings as errors (pyproject.toml), so these pytest.warns
tests are the ONLY sanctioned callers of the shims. (The PR-6
`repro.core.server` re-export shim served its one release and is gone;
importing it is now a plain ModuleNotFoundError.)
"""
import importlib
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import relay as relay_lib, sharding
from repro.core import client as client_lib, collab, vec_collab
from repro.data import partition, synthetic
from repro.models import mlp
from repro.relay import events, history, placement
from repro.types import (CollabConfig, FleetConfig, TrainConfig,
                         resolve_fleet)

SPEC = client_lib.ClientSpec(
    apply=lambda p, x: mlp.apply(p, x),
    head=lambda p: (p["head_w"], p["head_b"]))


def _fleet_args(n_clients=2, n=64, seed=0):
    x, y = synthetic.class_images(n, seed=0, noise=0.4)
    parts = partition.uniform_split(x, y, n_clients, seed=1)
    ccfg = CollabConfig(num_classes=10, d_feature=84)
    params = [mlp.init_mlp(k)
              for k in jax.random.split(jax.random.PRNGKey(seed), n_clients)]
    return ([SPEC] * n_clients, params, parts,
            synthetic.class_images(32, seed=9), ccfg, TrainConfig())


# ---------------------------------------------------------------------------
# placement primitives
# ---------------------------------------------------------------------------
def test_like_tags_every_leaf():
    tree = {"a": jnp.zeros((2,)), "b": (jnp.zeros(()), jnp.ones((3, 4)))}
    tags = placement.like(tree, placement.REPLICATED)
    assert jax.tree.structure(tags) == jax.tree.structure(tree)
    assert set(jax.tree.leaves(tags)) == {placement.REPLICATED}
    with pytest.raises(ValueError, match="unknown placement"):
        placement.like(tree, "diagonal")


def test_resolve_maps_tags_to_shardings():
    mesh = sharding.client_mesh(1)
    rep = placement.resolve(placement.REPLICATED, mesh)
    cl = placement.resolve(placement.CLIENT_SHARDED, mesh)
    assert rep.spec == jax.sharding.PartitionSpec()
    assert cl.spec == jax.sharding.PartitionSpec(placement.CLIENT_AXIS)
    tree = {"a": placement.REPLICATED, "b": placement.CLIENT_SHARDED}
    rs = placement.resolve(tree, mesh)
    assert rs["a"].spec == rep.spec and rs["b"].spec == cl.spec


def test_exchange_is_noop_off_mesh():
    x = {"p": jnp.arange(4.0), "q": jnp.ones((2, 3))}
    out = placement.exchange(x, None)
    assert out is x                                   # structurally free
    mesh = sharding.client_mesh(1)
    out = placement.exchange(x, mesh)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), x, out)


@pytest.mark.parametrize("policy", ["flat", "per_class", "staleness"])
def test_every_policy_declares_replicated_state(policy):
    """The relay IS the paper's shared pool: every policy's state leaves
    are REPLICATED, leaf for leaf."""
    pol = relay_lib.get_policy(policy)
    st = pol.init_state(CollabConfig(num_classes=4, d_feature=3), 3, seed=0)
    spec = pol.out_spec(st)
    assert jax.tree.structure(spec) == jax.tree.structure(st)
    assert set(jax.tree.leaves(spec)) == {placement.REPLICATED}


def test_pending_is_client_sharded_history_replicated():
    pending = events.init_pending(4, 2, 1, 4, 3)
    pspec = events.out_spec(pending)
    assert set(jax.tree.leaves(pspec)) == {placement.CLIENT_SHARDED}
    pol = relay_lib.get_policy("flat")
    st = pol.init_state(CollabConfig(num_classes=4, d_feature=3), 3, seed=0)
    hist = history.init(st, 2)
    assert set(jax.tree.leaves(history.out_spec(hist))) == {
        placement.REPLICATED}


# ---------------------------------------------------------------------------
# engine API: seq rejects mesh with a WHY, vec compiles once (1-device)
# ---------------------------------------------------------------------------
def test_sequential_oracle_rejects_mesh():
    with pytest.raises(ValueError, match="sequential oracle.*host-side"):
        collab.CollabTrainer(*_fleet_args(),
                             fleet=FleetConfig(mesh=sharding.client_mesh(1)))


def test_placement_round_step_compiles_once():
    vec = vec_collab.VectorizedCollabTrainer(
        *_fleet_args(n=96), seed=0,
        fleet=FleetConfig(mesh=sharding.client_mesh(1)))
    for _ in range(3):
        vec.run_round()
    assert vec._round_step._cache_size() == 1


# ---------------------------------------------------------------------------
# deprecation shims (the only sanctioned callers — see module docstring)
# ---------------------------------------------------------------------------
def test_legacy_trainer_kwargs_warn_and_still_work():
    args = _fleet_args()
    with pytest.warns(DeprecationWarning, match="repro:.*deprecated"):
        old = vec_collab.VectorizedCollabTrainer(
            *args, seed=0, policy="staleness", schedule="uniform_k:1")
    new = vec_collab.VectorizedCollabTrainer(
        *args, seed=0, fleet=FleetConfig(policy="staleness",
                                         participation="uniform_k:1"))
    ro, rn = old.run_round(), new.run_round()
    assert ro["participants"] == rn["participants"]
    np.testing.assert_array_equal(ro["accs"], rn["accs"])


def test_legacy_kwargs_warn_on_sequential_engine_too():
    with pytest.warns(DeprecationWarning, match="repro:"):
        collab.CollabTrainer(*_fleet_args(), policy="flat")


def test_mixing_fleet_and_legacy_kwargs_is_an_error():
    with pytest.raises(ValueError, match="not both"):
        resolve_fleet(FleetConfig(policy="flat"), clock="lognormal:2")
    with pytest.raises(ValueError, match="not both"):
        vec_collab.VectorizedCollabTrainer(
            *_fleet_args(), seed=0, fleet=FleetConfig(), policy="flat")


def test_resolve_fleet_passthrough_and_fold():
    assert resolve_fleet(None) == FleetConfig()
    f = FleetConfig(policy="per_class")
    assert resolve_fleet(f) is f
    with pytest.warns(DeprecationWarning, match="repro:"):
        g = resolve_fleet(schedule="uniform_k:2", mesh=None)
    assert g.participation == "uniform_k:2" and g.mesh is None


def test_core_server_shim_is_retired():
    with pytest.raises(ModuleNotFoundError):
        importlib.import_module("repro.core.server")


def test_no_internal_module_triggers_shims():
    """Importing the whole package tree must raise no repro: deprecation
    (the filterwarnings=error line in pyproject only covers test runs;
    this pins it for plain imports too)."""
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        for m in ("repro.core.vec_collab", "repro.core.collab",
                  "repro.relay", "repro.launch.train"):
            importlib.import_module(m)
