"""Unit tests for the paper's objective (Eq. 5-7, Theorem 1)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import losses

KEY = jax.random.PRNGKey(0)


def test_ce_matches_manual():
    logits = jnp.array([[2.0, 0.0, -1.0], [0.5, 0.5, 0.5]])
    labels = jnp.array([0, 2])
    want = -np.mean([np.log(np.exp(2) / (np.exp(2) + 1 + np.exp(-1))),
                     np.log(1 / 3)])
    np.testing.assert_allclose(losses.ce_loss(logits, labels), want,
                               rtol=1e-6)


def test_ce_mask():
    logits = jax.random.normal(KEY, (4, 5))
    labels = jnp.array([0, 1, 2, 3])
    m = jnp.array([1, 1, 0, 0])
    got = losses.ce_loss(logits, labels, mask=m)
    want = losses.ce_loss(logits[:2], labels[:2])
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_kd_zero_at_prototype():
    protos = jax.random.normal(KEY, (5, 8))
    labels = jnp.array([0, 3, 4])
    feats = protos[labels]
    assert float(losses.kd_loss(feats, protos, labels)) < 1e-10


def test_kd_is_mean_per_dim():
    protos = jnp.zeros((2, 16))
    feats = jnp.ones((1, 16)) * 2.0
    got = float(losses.kd_loss(feats, protos, jnp.array([0])))
    np.testing.assert_allclose(got, 4.0, rtol=1e-6)  # mean(2^2), not sum


def test_kd_valid_mask_excludes_empty_classes():
    protos = jnp.stack([jnp.zeros(4), jnp.full(4, 100.0)])
    feats = jnp.ones((2, 4))
    labels = jnp.array([0, 1])
    valid = jnp.array([True, False])
    got = float(losses.kd_loss(feats, protos, labels, valid=valid))
    np.testing.assert_allclose(got, 1.0, rtol=1e-6)  # only class 0 counted


def test_hhat_is_probability():
    s = jax.random.normal(KEY, (7, 10)) * 3
    t = jax.random.normal(jax.random.PRNGKey(1), (10, 10)) * 3
    h = losses.hhat_matrix(s, t)
    assert float(h.min()) >= 0.0 and float(h.max()) <= 1.0


def test_disc_perfect_discriminator_low_loss():
    # one-hot-ish student and teacher distributions aligned by class
    C = 6
    big = 50.0
    s_feats = jnp.eye(C) * big                       # d' == C for simplicity
    obs = jnp.eye(C) * big
    labels = jnp.arange(C)
    w = jnp.eye(C)                                   # τ = identity
    loss = float(losses.disc_loss(s_feats, obs, labels, w))
    assert loss < 1e-3, loss


def test_disc_chance_level_value():
    # uniform distributions: ĥ = 1/C for every pair
    C = 10
    s = jnp.zeros((4, C))
    obs = jnp.zeros((C, 8))
    w = jnp.zeros((8, C))
    labels = jnp.array([0, 1, 2, 3])
    got = float(losses.disc_loss(jnp.zeros((4, 8)), obs, labels, w))
    want = -np.log(1 / C) - (C - 1) * np.log(1 - 1 / C)
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_mi_bound_theorem1():
    # bound must satisfy I >= log K - L_disc and be <= log K
    l = jnp.asarray(1.3)
    b = losses.mi_lower_bound(l, K=9)
    np.testing.assert_allclose(float(b), np.log(9) - 1.3, rtol=1e-6)


def test_disc_sampled_excludes_self_negative():
    key = jax.random.PRNGKey(3)
    C, d, B = 50, 8, 4
    protos = jax.random.normal(KEY, (C, d))
    feats = jax.random.normal(jax.random.PRNGKey(2), (B, d))
    labels = jnp.array([0, 1, 2, 3])
    w = jax.random.normal(jax.random.PRNGKey(4), (d, C))
    l = losses.disc_loss_sampled(key, feats, protos, labels, w,
                                 num_negatives=16)
    assert np.isfinite(float(l)) and float(l) > 0


def test_fd_loss_zero_when_matching():
    mean_logits = jax.random.normal(KEY, (5, 5))
    labels = jnp.array([1, 4])
    logits = mean_logits[labels]
    assert float(losses.fd_loss(logits, mean_logits, labels)) < 1e-12
