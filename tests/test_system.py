"""End-to-end behaviour of the paper's system (CollabTrainer + RelayServer).

Short-horizon integration: these verify mechanism, not paper-scale accuracy
(benchmarks/ reproduce the tables at full round counts).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines, client as client_lib, collab, comm
from repro.data import partition, synthetic
from repro.models import cnn
from repro.types import CollabConfig, TrainConfig

SPEC = client_lib.ClientSpec(
    apply=lambda p, x: cnn.apply(p, x),
    head=lambda p: (p["head_w"], p["head_b"]))


def _setup(n_clients=2, n=400, mode="cors", **ck):
    x, y = synthetic.class_images(n, seed=0, noise=0.4)
    tx, ty = synthetic.class_images(500, seed=9, noise=0.4)
    parts = partition.uniform_split(x, y, n_clients, seed=1)
    ccfg = CollabConfig(mode=mode, num_classes=10, d_feature=84, **ck)
    tcfg = TrainConfig(batch_size=32)
    params = [cnn.init_cnn(k)
              for k in jax.random.split(jax.random.PRNGKey(0), n_clients)]
    return collab.CollabTrainer([SPEC] * n_clients, params, parts, (tx, ty),
                                ccfg, tcfg, seed=0)


def test_cors_learns_above_chance():
    tr = _setup(mode="cors", lambda_kd=2.0, lambda_disc=1.0)
    for _ in range(4):
        rec = tr.run_round()
    assert rec["acc_mean"] > 0.25          # 10 classes, chance = 0.1
    m = rec["metrics"][0]
    assert np.isfinite(m["kd"]) and np.isfinite(m["disc"])


def test_cors_comm_matches_formula():
    tr = _setup(mode="cors")
    tr.run_round()
    up, down = comm.cors_round_floats(10, 84, 1, 1, 2)
    assert tr.ledger.by_round[0] == (up, down)


def test_il_has_zero_comm():
    tr = _setup(mode="il")
    tr.run_round()
    assert tr.ledger.total_bytes == 0.0


def test_fedavg_syncs_models():
    tr = _setup(mode="fedavg")
    tr.run_round()
    p0, p1 = tr.clients[0].params, tr.clients[1].params
    for a, b in zip(jax.tree.leaves(p0), jax.tree.leaves(p1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_fedavg_aggregate_is_mean():
    ps = [{"w": jnp.ones((2, 2)) * v} for v in (1.0, 3.0)]
    avg = baselines.fedavg_aggregate(ps)
    np.testing.assert_allclose(avg["w"], 2.0)


def test_fd_mode_shares_logit_means():
    tr = _setup(mode="fd", lambda_kd=1.0)
    tr.run_round()
    tr.run_round()
    assert hasattr(tr.server, "mean_logits")
    assert tr.server.mean_logits.shape == (10, 10)


def test_relay_excludes_own_observations():
    tr = _setup(mode="cors")
    tr.run_round()
    srv = tr.server
    owners = {o["owner"] for o in srv.obs_buffer}
    assert 1 in owners


def test_server_is_relay_only():
    """The server never holds or touches model weights (paper's design)."""
    tr = _setup(mode="cors")
    tr.run_round()
    assert not hasattr(tr.server, "model")
    assert not hasattr(tr.server, "params")


def test_heterogeneous_architectures_collaborate():
    """CoRS works across different client model architectures (the paper's
    tunable-collaboration selling point; FedAvg cannot do this)."""
    x, y = synthetic.class_images(300, seed=0, noise=0.4)
    tx, ty = synthetic.class_images(200, seed=9, noise=0.4)
    parts = partition.uniform_split(x, y, 2, seed=1)
    keys = jax.random.split(jax.random.PRNGKey(0), 2)
    params = [cnn.init_cnn(keys[0], width=1),
              cnn.init_cnn(keys[1], width=2)]       # different capacity
    ccfg = CollabConfig(mode="cors", num_classes=10, d_feature=84,
                        lambda_kd=2.0, lambda_disc=1.0)
    tr = collab.CollabTrainer([SPEC] * 2, params, parts, (tx, ty), ccfg,
                              TrainConfig(batch_size=32), seed=0)
    rec = tr.run_round()
    assert np.isfinite(rec["acc_mean"])
    rec = tr.run_round()
    assert np.isfinite(rec["metrics"][1]["disc"])
