"""Vectorized multi-client engine: equivalence with the sequential oracle,
ring-buffer mechanics, and the relay's degenerate-pool behavior."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import relay as relay_lib, sharding
from repro.core import client as client_lib, collab, vec_collab
from repro.data import partition, synthetic
from repro.models import cnn, mlp
from repro.relay import flat as flat_relay
from repro.types import CollabConfig, FleetConfig, TrainConfig

SPEC = client_lib.ClientSpec(
    apply=lambda p, x: cnn.apply(p, x),
    head=lambda p: (p["head_w"], p["head_b"]))

MLP_SPEC = client_lib.ClientSpec(
    apply=lambda p, x: mlp.apply(p, x),
    head=lambda p: (p["head_w"], p["head_b"]))


def _build(mode, engine, n_clients=2, n=384, seed=0, mesh=None):
    x, y = synthetic.class_images(n, seed=0, noise=0.4)
    tx, ty = synthetic.class_images(256, seed=9, noise=0.4)
    parts = partition.uniform_split(x, y, n_clients, seed=1)
    ccfg = CollabConfig(mode=mode, num_classes=10, d_feature=84,
                        lambda_kd=2.0 if mode in ("cors", "fd") else 0.0,
                        lambda_disc=1.0 if mode == "cors" else 0.0)
    tcfg = TrainConfig(batch_size=32)
    params = [cnn.init_cnn(k)
              for k in jax.random.split(jax.random.PRNGKey(seed), n_clients)]
    if engine == "seq":
        return collab.CollabTrainer([SPEC] * n_clients, params, parts,
                                    (tx, ty), ccfg, tcfg, seed=seed)
    return vec_collab.VectorizedCollabTrainer(
        [SPEC] * n_clients, params, parts, (tx, ty), ccfg, tcfg, seed=seed,
        fleet=FleetConfig(mesh=mesh))


# ---------------------------------------------------------------------------
# tentpole: the vectorized engine IS the sequential oracle, batched
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["cors", "fd", "il", "fedavg"])
def test_vectorized_matches_sequential(mode):
    """Same seeds/partitions -> per-round acc within float tolerance and
    IDENTICAL comm-ledger floats, for every mode. Both engines share the
    relay state functions and the round key schedule, so the only slack is
    vmap-batched float association."""
    seq = _build(mode, "seq")
    vec = _build(mode, "vec")
    for _ in range(3):
        rs, rv = seq.run_round(), vec.run_round()
        assert abs(rs["acc_mean"] - rv["acc_mean"]) < 2e-2
        np.testing.assert_allclose(rs["accs"], rv["accs"], atol=2e-2)
    assert seq.ledger.by_round == vec.ledger.by_round
    assert seq.ledger.total_bytes == vec.ledger.total_bytes


def test_vectorized_metrics_match_sequential():
    seq = _build("cors", "seq")
    vec = _build("cors", "vec")
    ms = seq.run_round()["metrics"]
    mv = vec.run_round()["metrics"]
    assert [sorted(m) for m in ms] == [sorted(m) for m in mv]
    for a, b in zip(ms, mv):
        for k in a:
            np.testing.assert_allclose(a[k], b[k], rtol=1e-3, atol=1e-5)


def test_vectorized_is_model_agnostic():
    """The engine takes any ClientSpec: equivalence also holds for the MLP
    client used by benchmarks/scaling_clients.py."""
    x, y = synthetic.class_images(256, seed=0, noise=0.4)
    tx, ty = synthetic.class_images(128, seed=9, noise=0.4)
    parts = partition.uniform_split(x, y, 4, seed=1)
    ccfg = CollabConfig(mode="cors", num_classes=10, d_feature=84,
                        lambda_kd=2.0, lambda_disc=1.0)
    tcfg = TrainConfig(batch_size=32)
    params = [mlp.init_mlp(k)
              for k in jax.random.split(jax.random.PRNGKey(0), 4)]
    seq = collab.CollabTrainer([MLP_SPEC] * 4, params, parts, (tx, ty),
                               ccfg, tcfg, seed=0)
    vec = vec_collab.VectorizedCollabTrainer(
        MLP_SPEC, params, parts, (tx, ty), ccfg, tcfg, seed=0)
    for _ in range(2):
        rs, rv = seq.run_round(), vec.run_round()
        np.testing.assert_allclose(rs["accs"], rv["accs"], atol=2e-2)
    assert seq.ledger.by_round == vec.ledger.by_round


def test_vectorized_placement_path_matches():
    """mesh path (placement-resolved jit shardings + one `exchange` per
    round, relay/placement.py) computes the same rounds as the plain vmap
    path — and compiles the round step exactly once."""
    plain = _build("cors", "vec")
    mesh = sharding.client_mesh(1)
    mapped = _build("cors", "vec", mesh=mesh)
    for _ in range(2):
        rp, rm = plain.run_round(), mapped.run_round()
        np.testing.assert_allclose(rp["acc_mean"], rm["acc_mean"], atol=2e-2)
    assert mapped._round_step._cache_size() == 1


def test_vectorized_buckets_heterogeneous_specs():
    """Mixed-spec fleets no longer fall back to the sequential oracle: the
    trainer groups clients into stackable buckets (one vmapped step each)
    around the shared relay. FedAvg stays homogeneous-only, with an error
    that says why (it averages whole weight vectors)."""
    other = client_lib.ClientSpec(
        apply=lambda p, x: cnn.apply(p, x),
        head=lambda p: (p["head_w"], p["head_b"]))
    x, y = synthetic.class_images(64, seed=0)
    parts = partition.uniform_split(x, y, 2, seed=1)
    params = [cnn.init_cnn(k) for k in
              jax.random.split(jax.random.PRNGKey(0), 2)]
    tr = vec_collab.VectorizedCollabTrainer(
        [SPEC, other], params, parts, (x, y),
        CollabConfig(num_classes=10, d_feature=84), TrainConfig())
    assert tr.hetero and [list(b.ids) for b in tr.buckets] == [[0], [1]]
    with pytest.raises(ValueError, match="FedAvg.*shared architecture"):
        vec_collab.VectorizedCollabTrainer(
            [SPEC, other], params, parts, (x, y),
            CollabConfig(mode="fedavg", num_classes=10, d_feature=84),
            TrainConfig())
    # mesh × hetero used to raise; under the placement API each bucket's
    # stack is client-sharded over the same axis and the shared commit is
    # the exchange point, so it just runs — and matches the plain path.
    meshed = vec_collab.VectorizedCollabTrainer(
        [SPEC, other], params, parts, (x, y),
        CollabConfig(num_classes=10, d_feature=84), TrainConfig(),
        fleet=FleetConfig(mesh=sharding.client_mesh(1)))
    assert meshed.hetero
    rp, rm = tr.run_round(), meshed.run_round()
    np.testing.assert_allclose(rp["accs"], rm["accs"], atol=2e-2)


def test_client_params_roundtrip():
    vec = _build("il", "vec")
    p0 = vec.client_params(0)
    assert set(p0) == set(cnn.init_cnn(jax.random.PRNGKey(0)))
    assert p0["head_w"].shape == (84, 10)


# ---------------------------------------------------------------------------
# ring buffer mechanics
# ---------------------------------------------------------------------------
def _tiny_state(cap=4, C=3, d=2, m_down=1):
    ccfg = CollabConfig(num_classes=C, d_feature=d, m_down=m_down)
    return flat_relay.init_relay_state(ccfg, d, seed=0, capacity=cap)


def test_ring_buffer_appends_in_order_and_wraps():
    st = _tiny_state(cap=4)
    assert int(st.ptr) == 1                       # one seeded slot
    rows = lambda v, k: jnp.full((k, 3, 2), float(v))
    vrows = lambda k: jnp.ones((k, 3), bool)
    st = flat_relay.buffer_append(st, rows(1.0, 2), vrows(2),
                                  jnp.full((2,), 0, jnp.int32))
    st = flat_relay.buffer_append(st, rows(2.0, 2), vrows(2),
                                  jnp.full((2,), 1, jnp.int32))
    # 1 seed + 4 uploads into cap=4: the wrap overwrote slot 0 (the seed)
    assert int(st.ptr) == 1
    np.testing.assert_array_equal(np.asarray(st.owner), [1, 0, 0, 1])
    np.testing.assert_allclose(st.obs[0], 2.0)    # newest won the slot
    assert not bool(jnp.any(st.owner == relay_lib.EMPTY_OWNER))


def test_sample_teacher_excludes_own_uploads():
    st = _tiny_state(cap=4)
    # fill: client 0's rows are all-zeros, client 1's rows are all-ones
    st = st._replace(
        obs=jnp.stack([jnp.zeros((3, 2)), jnp.zeros((3, 2)),
                       jnp.ones((3, 2)), jnp.ones((3, 2))]),
        valid=jnp.ones((4, 3), bool),
        owner=jnp.asarray([0, 0, 1, 1], jnp.int32))
    for s in range(8):
        t = flat_relay.sample_teacher(st, 0, 2, jax.random.PRNGKey(s))
        np.testing.assert_allclose(t["obs"], 1.0)  # never its own (zeros)
        t = flat_relay.sample_teacher(st, 1, 2, jax.random.PRNGKey(s))
        np.testing.assert_allclose(t["obs"], 0.0)


def test_sample_teacher_falls_back_to_own_pool():
    """All filled slots owned by the requester -> fall back to the whole
    filled buffer rather than crashing or returning garbage."""
    st = _tiny_state(cap=2)
    st = st._replace(owner=jnp.asarray([0, relay_lib.EMPTY_OWNER],
                                       jnp.int32),
                     valid=st.valid.at[0].set(True))
    t = flat_relay.sample_teacher(st, 0, 3, jax.random.PRNGKey(0))
    assert t["obs"].shape == (3, 3, 2)
    np.testing.assert_allclose(t["obs"], np.broadcast_to(st.obs[0], (3, 3, 2)))
    assert bool(jnp.all(t["valid_o"]))


# ---------------------------------------------------------------------------
# regression: relay before ANY upload is well-formed (the old list server
# synthesized a fallback entry without an "owner" key)
# ---------------------------------------------------------------------------
def test_relay_before_any_upload_is_well_formed():
    ccfg = CollabConfig(num_classes=5, d_feature=3, m_down=2)
    srv = relay_lib.RelayServer(ccfg, 3, seed=0)
    t = srv.relay(0, 2, jax.random.PRNGKey(0))
    assert set(t) == {"global_protos", "valid_g", "obs", "valid_o",
                      "obs_pick", "mean_logits"}
    assert t["obs"].shape == (2, 5, 3)
    assert t["mean_logits"].shape == (5, 5)
    assert bool(jnp.all(jnp.isfinite(t["obs"])))
    # every buffer entry — including server-seeded ones — carries an owner
    assert all("owner" in o for o in srv.obs_buffer)
    assert {o["owner"] for o in srv.obs_buffer} == {relay_lib.SEED_OWNER}


def test_relay_on_fully_empty_buffer_returns_invalid_teacher():
    ccfg = CollabConfig(num_classes=4, d_feature=2, m_down=1)
    st = flat_relay.init_relay_state(ccfg, 2, capacity=3)
    st = st._replace(owner=jnp.full((3,), relay_lib.EMPTY_OWNER, jnp.int32))
    t = flat_relay.sample_teacher(st, 0, 1, jax.random.PRNGKey(0))
    np.testing.assert_allclose(t["obs"], 0.0)
    assert not bool(jnp.any(t["valid_o"]))


# ---------------------------------------------------------------------------
# satellite: evaluate() must not re-jit per round
# ---------------------------------------------------------------------------
def test_evaluate_caches_one_fn_per_spec():
    tr = _build("il", "seq", n_clients=2)
    tr.run_round()
    tr.run_round()
    assert len(tr._eval_cache) == 1               # both clients share SPEC
    fn = tr._eval_cache[SPEC]
    tr.run_round()
    assert tr._eval_cache[SPEC] is fn
