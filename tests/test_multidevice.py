"""Multi-device shard_map validation (ROADMAP item).

The `mesh=` path in core/vec_collab.py was only ever exercised on a 1-device
mesh, where psum / all_gather are identities. This forces FOUR host CPU
devices in a subprocess (XLA_FLAGS must be set before jax import, hence the
subprocess) and checks that the sharded round step — psum prototype merge +
observation all-gather into the replicated ring — computes the same rounds
as the plain single-device vmap path at N=8 clients.
"""
import os
import subprocess
import sys

_SCRIPT = r"""
import jax
import numpy as np

assert jax.device_count() == 4, jax.devices()

from repro import sharding
from repro.core import client as client_lib, vec_collab
from repro.data import partition, synthetic
from repro.models import mlp
from repro.types import CollabConfig, TrainConfig

SPEC = client_lib.ClientSpec(
    apply=lambda p, x: mlp.apply(p, x),
    head=lambda p: (p["head_w"], p["head_b"]))
N = 8

def build(mesh):
    x, y = synthetic.class_images(256, seed=0, noise=0.4)
    tx, ty = synthetic.class_images(128, seed=9, noise=0.4)
    parts = partition.uniform_split(x, y, N, seed=1)
    ccfg = CollabConfig(mode="cors", num_classes=10, d_feature=84,
                       lambda_kd=2.0, lambda_disc=1.0)
    params = [mlp.init_mlp(k)
              for k in jax.random.split(jax.random.PRNGKey(0), N)]
    return vec_collab.VectorizedCollabTrainer(
        [SPEC] * N, params, parts, (tx, ty), ccfg,
        TrainConfig(batch_size=16), seed=0, mesh=mesh)

plain = build(None)
mesh = sharding.client_mesh(4)          # 2 clients per device
mapped = build(mesh)
for _ in range(2):
    rp, rm = plain.run_round(), mapped.run_round()
    np.testing.assert_allclose(rp["accs"], rm["accs"], atol=2e-2)
# the replicated relay state must track the single-device one: exact ring
# bookkeeping, float-tolerant observations
sp, sm = plain.relay_state, mapped.relay_state
np.testing.assert_array_equal(np.asarray(sp.ptr), np.asarray(sm.ptr))
np.testing.assert_array_equal(np.asarray(sp.owner), np.asarray(sm.owner))
np.testing.assert_array_equal(np.asarray(sp.valid), np.asarray(sm.valid))
np.testing.assert_allclose(np.asarray(sp.obs), np.asarray(sm.obs),
                           atol=5e-3)
np.testing.assert_allclose(np.asarray(sp.global_protos),
                           np.asarray(sm.global_protos), atol=5e-3)
print("MULTIDEVICE_OK")
"""


def test_shard_map_4_devices_matches_single_device():
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                        + env.get("XLA_FLAGS", ""))
    env["JAX_PLATFORMS"] = "cpu"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = (os.path.join(root, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=540)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-4000:])
    assert "MULTIDEVICE_OK" in out.stdout
