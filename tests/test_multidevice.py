"""Multi-device placement-path validation (relay/placement.py).

The `FleetConfig.mesh` path in core/vec_collab.py is exercised on a
1-device mesh by the in-process suites, where any collective is an
identity. This forces FOUR host CPU devices in a subprocess (XLA_FLAGS
must be set before jax import, hence the subprocess) and runs the
seq/vec oracle harness (tests/oracles.py) against the placement-aware
round step for every composition the mesh used to reject: synchronous,
async (client-sharded pending buffer), download lag (replicated history
ring), static-k compaction (k not divisible by the device count — GSPMD
pads the in-jit block) and bucketed heterogeneous fleets (bucket sizes
not divisible by the device count — those stacks fall back to
replicated). Exact ring bookkeeping, commit lists and ledgers;
float-tolerant observations; compile-once on the fused steps.
"""
import os
import subprocess
import sys

_SCRIPT = r"""
import jax
import numpy as np

assert jax.device_count() == 4, jax.devices()

from oracles import run_matched
from repro import sharding
from repro.core import client as client_lib, collab, vec_collab
from repro.data import partition, synthetic
from repro.models import mlp
from repro.types import CollabConfig, FleetConfig, TrainConfig

SPEC = client_lib.ClientSpec(
    apply=lambda p, x: mlp.apply(p, x),
    head=lambda p: (p["head_w"], p["head_b"]))
SPEC_B = client_lib.ClientSpec(
    apply=lambda p, x: mlp.apply(p, x),
    head=lambda p: (p["head_w"], p["head_b"]))
N = 8

def build(engine, mesh=None, policy=None, schedule=None, clock=None,
          download_clock=None, hetero=False, n=N, telemetry=None):
    x, y = synthetic.class_images(192, seed=0, noise=0.4)
    tx, ty = synthetic.class_images(96, seed=9, noise=0.4)
    parts = partition.uniform_split(x, y, n, seed=1)
    ccfg = CollabConfig(mode="cors", num_classes=10, d_feature=84,
                        lambda_kd=2.0, lambda_disc=1.0)
    keys = jax.random.split(jax.random.PRNGKey(0), n)
    if hetero:
        # 5-vs-3 split: bucket sizes NOT divisible by the 4 devices
        specs = [SPEC if i % 3 else SPEC_B for i in range(n)]
        params = [mlp.init_mlp(k, hidden=64 if i % 3 else 96)
                  for i, k in enumerate(keys)]
    else:
        specs = [SPEC] * n
        params = [mlp.init_mlp(k) for k in keys]
    cls = (collab.CollabTrainer if engine == "seq"
           else vec_collab.VectorizedCollabTrainer)
    return cls(specs, params, parts, (tx, ty), ccfg,
               TrainConfig(batch_size=16), seed=0, telemetry=telemetry,
               fleet=FleetConfig(mesh=mesh, policy=policy,
                                 participation=schedule, clock=clock,
                                 download_clock=download_clock))

mesh = sharding.client_mesh(4)          # 2 clients per device

# state at rest cannot hold an uneven sharding: the TOTAL client axis
# must divide the mesh (uneven hetero buckets are the sanctioned case)
try:
    build("vec", mesh=mesh, n=6)
except ValueError as e:
    assert "must divide" in str(e), e
else:
    raise SystemExit("N=6 on a 4-device mesh should be rejected")
print("UNEVEN_GUARD_OK")

# sync: mesh path vs the sequential oracle, compile-once
vec = build("vec", mesh=mesh)
run_matched(build("seq"), vec, rounds=2)
assert vec._round_step._cache_size() == 1
print("SYNC_OK")

# async: client-sharded pending buffer, event-ordered commits
vec = build("vec", mesh=mesh, policy="staleness", clock="lognormal:2")
run_matched(build("seq", policy="staleness", clock="lognormal:2"), vec,
            rounds=3)
assert vec._round_step._cache_size() == 1
print("ASYNC_OK")

# download lag: replicated history ring, local stale gathers
vec = build("vec", mesh=mesh, policy="per_class",
            download_clock="lognormal:2")
run_matched(build("seq", policy="per_class", download_clock="lognormal:2"),
            vec, rounds=3)
assert vec._round_step._cache_size() == 1
print("DOWNLOAD_OK")

# static-k compaction: k=3 participants on 4 devices (GSPMD pads)
vec = build("vec", mesh=mesh, schedule="uniform_k:3")
assert vec._k_active == 3
run_matched(build("seq", schedule="uniform_k:3"), vec, rounds=2)
print("STATICK_OK")

# hetero buckets (5 + 3 clients) sharing one relay over the mesh
vec = build("vec", mesh=mesh, hetero=True)
assert vec.hetero and len(vec.buckets) == 2
run_matched(build("seq", hetero=True), vec, rounds=2)
print("HETERO_OK")

# telemetry on the mesh: every RoundTelemetry leaf is declared REPLICATED
# (obs.metrics.out_spec) and run_matched pins its integer leaves against
# the oracle bit-for-bit; the extra output must not cost a recompile
vec = build("vec", mesh=mesh, policy="staleness", clock="lognormal:2",
            telemetry=True)
run_matched(build("seq", policy="staleness", clock="lognormal:2",
                  telemetry=True), vec, rounds=3)
assert vec._round_step._cache_size() == 1
t = vec.history[-1]["telemetry"]
assert "occupancy" in t and "commit_hist" in t
print("TELEMETRY_OK")

# async x download-lag x mesh in one run: the full composition
vec = build("vec", mesh=mesh, clock="lognormal:2",
            download_clock="lognormal:2")
run_matched(build("seq", clock="lognormal:2", download_clock="lognormal:2"),
            vec, rounds=3)
print("COMPOSED_OK")

print("MULTIDEVICE_OK")
"""


def test_placement_4_devices_matches_oracle():
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                        + env.get("XLA_FLAGS", ""))
    env["JAX_PLATFORMS"] = "cpu"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = (os.path.join(root, "src") + os.pathsep
                         + os.path.join(root, "tests") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=540)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-4000:])
    for marker in ("UNEVEN_GUARD_OK", "SYNC_OK", "ASYNC_OK", "DOWNLOAD_OK",
                   "STATICK_OK", "HETERO_OK", "TELEMETRY_OK",
                   "COMPOSED_OK", "MULTIDEVICE_OK"):
        assert marker in out.stdout, out.stdout
