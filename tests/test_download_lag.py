"""Download-lag relay history (src/repro/relay/history.py + sim download
clocks).

The tentpole invariant: when clients read STALE relay snapshots — teacher
pools and global prototypes as of round `t − d(client, t)` — the
vectorized engine's in-step history ring and the sequential oracle's
host-side snapshot list evolve IDENTICAL relay state, commit lists and
comm ledgers, across every relay policy × download clock, with and
without event-ordered upload lag on top. Plus: the `H_max = 1` (and
all-delay-0) machinery is bit-identical to the history-free engines, the
ring itself matches the oracle's snapshots slot by slot, downlink billing
is invariant under the delay map (billed at read), the lagged step never
retraces, and the LM-path `make_download_lag_round_sync` serves exactly
the prototypes of round `t − d`.

The full policy × download-clock × upload-clock cross products live behind
the `slow` marker (separate non-blocking CI job); tier-1 runs a diagonal.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from oracles import assert_ledgers_equal, assert_states_match, run_matched
from repro import sim
from repro.core import client as client_lib, collab, prototypes, vec_collab
from repro.data import partition, synthetic
from repro.launch import train
from repro.models import mlp
from repro.relay import history
from repro.types import CollabConfig, FleetConfig, TrainConfig

SPEC = client_lib.ClientSpec(
    apply=lambda p, x: mlp.apply(p, x),
    head=lambda p: (p["head_w"], p["head_b"]))
SPEC_B = client_lib.ClientSpec(
    apply=lambda p, x: mlp.apply(p, x),
    head=lambda p: (p["head_w"], p["head_b"]))

POLICIES = ["flat", "per_class", "staleness"]
DL_CLOCKS = ["homogeneous:1", "lognormal:2", "periodic:2,3"]


def _build(engine, policy, dl_clock, clock=None, schedule=None, mode="cors",
           n_clients=4, n=192, seed=0, hetero=False, mesh=None):
    x, y = synthetic.class_images(n, seed=0, noise=0.4)
    tx, ty = synthetic.class_images(96, seed=9, noise=0.4)
    parts = partition.uniform_split(x, y, n_clients, seed=1)
    ccfg = CollabConfig(mode=mode, num_classes=10, d_feature=84,
                        lambda_kd=2.0,
                        lambda_disc=1.0 if mode == "cors" else 0.0)
    tcfg = TrainConfig(batch_size=16)
    keys = jax.random.split(jax.random.PRNGKey(seed), n_clients)
    if hetero:
        specs = [SPEC if i % 2 == 0 else SPEC_B for i in range(n_clients)]
        params = [mlp.init_mlp(k, hidden=64 if i % 2 == 0 else 96)
                  for i, k in enumerate(keys)]
    else:
        specs = [SPEC] * n_clients
        params = [mlp.init_mlp(k) for k in keys]
    cls = (collab.CollabTrainer if engine == "seq"
           else vec_collab.VectorizedCollabTrainer)
    return cls(specs, params, parts, (tx, ty), ccfg, tcfg, seed=seed,
               fleet=FleetConfig(policy=policy, participation=schedule,
                                 clock=clock, download_clock=dl_clock,
                                 mesh=mesh))


# ---------------------------------------------------------------------------
# tentpole: seq host-replayed snapshots == vec history ring
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy,dl_clock", list(zip(POLICIES, DL_CLOCKS)))
def test_download_lag_seq_vec_equivalence(policy, dl_clock):
    """Tier-1 diagonal of the policy × download-clock matrix (the full
    cross product runs under -m slow)."""
    run_matched(_build("seq", policy, dl_clock),
                _build("vec", policy, dl_clock))


@pytest.mark.slow
@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("dl_clock", DL_CLOCKS)
@pytest.mark.parametrize("up_clock", [None, "lognormal:2"])
def test_download_lag_full_matrix(policy, dl_clock, up_clock):
    """Every relay policy × download clock × {upload lag off, on}."""
    run_matched(_build("seq", policy, dl_clock, clock=up_clock),
                _build("vec", policy, dl_clock, clock=up_clock))


def test_upload_and_download_lag_compose():
    """Event-ordered late commits + stale snapshot reads in ONE run: the
    two clock axes must not interfere (a client can distill against an
    old snapshot while its own upload is still in flight)."""
    run_matched(_build("seq", "staleness", "lognormal:2",
                       clock="lognormal:2"),
                _build("vec", "staleness", "lognormal:2",
                       clock="lognormal:2"), rounds=4)


def test_download_lag_partial_participation_and_fd():
    """Variable-count schedule (incl. possible zero-participant rounds,
    which must still advance the ring) + FD-mode logit protos."""
    run_matched(_build("seq", "flat", "periodic:2,3", schedule="bernoulli:0.5",
                       mode="fd"),
                _build("vec", "flat", "periodic:2,3", schedule="bernoulli:0.5",
                       mode="fd"), rounds=4)


def test_download_lag_static_k_compaction():
    """Unlike upload lag, download lag composes with static-k compaction:
    only participants read, so the gathered (k, ...) block covers every
    stale read. The compacted engine must still match the oracle."""
    seq = _build("seq", "flat", "lognormal:2", schedule="uniform_k:2")
    vec = _build("vec", "flat", "lognormal:2", schedule="uniform_k:2")
    assert vec._k_active == 2                    # compaction stays ON
    run_matched(seq, vec)


def test_download_lag_hetero_buckets():
    """Two interleaved buckets read from ONE shared history ring."""
    run_matched(_build("seq", "staleness", "periodic:2,3", hetero=True),
                _build("vec", "staleness", "periodic:2,3", hetero=True))


@pytest.mark.slow
@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("dl_clock", DL_CLOCKS)
def test_async_hetero_download_lag_matrix(policy, dl_clock):
    """The heaviest cross product: bucketed fleets × event-ordered upload
    lag × download lag, per policy × download clock."""
    run_matched(
        _build("seq", policy, dl_clock, clock="lognormal:2", hetero=True),
        _build("vec", policy, dl_clock, clock="lognormal:2", hetero=True))


# ---------------------------------------------------------------------------
# H_max = 1 / all-delay-0 machinery is bit-identical to today's engines
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("d_max", [0, 2])
def test_delay0_machinery_bit_identical(d_max):
    """A homogeneous delay-0 download clock forces the history machinery
    (H_max = d_max + 1 ring, per-client gathers, in-step push) with every
    read at delay 0: both engines must match their download_clock=None
    selves bit-for-bit — the acceptance anchor for H_max = 1 (d_max=0)
    and for deeper rings whose stale slots are never read (d_max=2)."""
    for engine in ("seq", "vec"):
        a = _build(engine, "staleness", sim.HomogeneousClock(0, d_max=d_max),
                   n_clients=3)
        b = _build(engine, "staleness", None, n_clients=3)
        if engine == "vec":
            assert a._lagged and not b._lagged
        for _ in range(2):
            ra, rb = a.run_round(), b.run_round()
            assert ra["commits"] == rb["commits"]
            assert ra["accs"] == rb["accs"]
        sa = a.server.state if engine == "seq" else a.relay_state
        sb = b.server.state if engine == "seq" else b.relay_state
        jax.tree.map(lambda x, y: np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y)), sa, sb)
        assert_ledgers_equal(a.ledger, b.ledger)


def test_history_ring_matches_oracle_snapshots():
    """Slot-by-slot ring equality: after matched runs, the vectorized
    ring's snapshot at every depth d equals the oracle's host-side
    _snaps[d] — and each snapshot's clock is the merge count as of that
    round (the stale global prototypes a depth-d reader is served)."""
    seq = _build("seq", "per_class", "homogeneous:2")
    vec = _build("vec", "per_class", "homogeneous:2")
    rounds = 5
    run_matched(seq, vec, rounds=rounds)
    h_max = vec._h_max
    assert h_max == 3 and seq._h_max == 3
    for d in range(h_max):
        snap_s = seq._snapshot(d)
        snap_v = history.read_at(vec.hist, d)
        assert_states_match(snap_s, snap_v)
        # full participation + delay-0 uploads: one merge per round
        assert int(np.asarray(snap_v.clock)) == rounds - d
    # reads deeper than the ring clamp to the oldest retained snapshot
    deep = history.read_at(vec.hist, h_max + 3)
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x), np.asarray(y)),
        history.read_at(vec.hist, h_max - 1), deep)


def test_download_lag_composes_with_mesh():
    """download-lag × mesh used to raise ("history ring is an off-mesh
    construct"); under the placement API the ring is REPLICATED
    (history.out_spec) so the per-client stale gathers stay local — it
    runs, matches the oracle exactly, and still compiles once."""
    from repro import sharding
    seq = _build("seq", "flat", "lognormal:2")
    vec = _build("vec", "flat", "lognormal:2", mesh=sharding.client_mesh(1))
    run_matched(seq, vec)
    assert vec._round_step._cache_size() == 1


def test_download_lag_step_compiles_once():
    """H_max is static, per-round delays are traced: 3 rounds = 1 compile,
    for both the sync-lagged and the async×lagged fused steps."""
    vec = _build("vec", "per_class", "lognormal:2")
    for _ in range(3):
        vec.run_round()
    assert vec._round_step._cache_size() == 1
    vec = _build("vec", "flat", "periodic:2,3", clock="lognormal:2")
    for _ in range(3):
        vec.run_round()
    assert vec._round_step._cache_size() == 1


# ---------------------------------------------------------------------------
# billing: downlink at read — invariant under the delay map
# ---------------------------------------------------------------------------
def test_downlink_billed_at_read_invariant_to_delay_map():
    """A stale read still crosses the wire at read time, so the ledger of
    a lagged run equals the round-fresh run's bit-for-bit under the same
    schedule — the delay map can shift WHAT is read, never what is
    billed."""
    a = _build("seq", "flat", "lognormal:3", n_clients=4)
    b = _build("seq", "flat", None, n_clients=4)
    for _ in range(4):
        a.run_round()
        b.run_round()
    assert_ledgers_equal(a.ledger, b.ledger)


# ---------------------------------------------------------------------------
# sim: download clocks
# ---------------------------------------------------------------------------
def test_download_clock_decorrelated_but_deterministic():
    up = sim.get_clock("lognormal:3", seed=4)
    dl = sim.get_download_clock("lognormal:3", seed=4)
    dl2 = sim.get_download_clock("lognormal:3", seed=4)
    assert dl.d_max == 3
    for r in range(5):
        np.testing.assert_array_equal(dl.delays(r, 8), dl2.delays(r, 8))
        assert (dl.delays(r, 8) <= 3).all() and (dl.delays(r, 8) >= 0).all()
    # same spec + same seed must NOT alias the upload clock's draws
    assert any(not np.array_equal(up.delays(r, 32), dl.delays(r, 32))
               for r in range(5))
    assert sim.get_download_clock(None) is None
    assert sim.get_download_clock("none") is None
    c = sim.HomogeneousClock(1)
    assert sim.get_download_clock(c, seed=9) is c


def test_periodic_download_clock_ages_forward():
    """A duty-cycled downloader's snapshot age must GROW between syncs
    (rounds SINCE its last window) and reset at the next one — the
    time-forward mirror of PeriodicClock's rounds-UNTIL-next-window
    upload delay, which would make observed history run backwards."""
    dl = sim.get_download_clock("periodic:4,3")
    assert isinstance(dl, sim.PeriodicSyncClock)
    ages = np.array([dl.delays(t, 1)[0] for t in range(7)])
    np.testing.assert_array_equal(ages, [0, 1, 2, 0, 1, 2, 0])
    d6 = [dl.delays(t, 6) for t in range(12)]
    for t in range(1, 12):
        step = d6[t] - d6[t - 1]
        assert ((step == 1) | (d6[t] == 0)).all()    # +1 or fresh sync


# ---------------------------------------------------------------------------
# LM-scale download-lag round sync (launch/train.py)
# ---------------------------------------------------------------------------
def test_download_lag_round_sync_serves_stale_protos():
    ccfg = CollabConfig(num_classes=4, d_feature=3)
    init_h, rs_lag, read_at = train.make_download_lag_round_sync(ccfg,
                                                                 h_max=3)
    rs_sync = train.make_round_sync(ccfg)
    mk_state = lambda: train.TrainState(None, None,
                                        prototypes.init_state(4, 3),
                                        jnp.zeros((), jnp.int32))
    state, state_s = mk_state(), mk_state()
    hist = init_h(4, 3)
    rng = np.random.default_rng(0)
    per_round = []
    for r in range(5):
        stats = prototypes.ProtoState(
            jnp.asarray(rng.normal(size=(3, 4, 3)), jnp.float32),
            jnp.asarray(rng.random((3, 4)), jnp.float32))
        state, hist = rs_lag(state, hist, stats)
        state_s = rs_sync(state_s, stats)
        per_round.append(state.proto)
    # the merge itself is untouched by the ring
    np.testing.assert_allclose(np.asarray(state.proto.sum),
                               np.asarray(state_s.proto.sum), atol=1e-6)
    # read_at(d) is the post-merge proto of d rounds ago; deeper reads
    # clamp to the oldest retained snapshot
    for d in range(3):
        got = read_at(hist, jnp.asarray(d, jnp.int32))
        np.testing.assert_array_equal(np.asarray(got.sum),
                                      np.asarray(per_round[4 - d].sum))
    # per-client vectorized reads (one stale proto per client)
    got = read_at(hist, jnp.asarray([0, 2, 1], jnp.int32))
    for j, d in enumerate([0, 2, 1]):
        np.testing.assert_array_equal(np.asarray(got.sum[j]),
                                      np.asarray(per_round[4 - d].sum))

    # h_max=1 degenerates to make_round_sync exactly
    init1, rs1, read1 = train.make_download_lag_round_sync(ccfg, h_max=1)
    stats = prototypes.ProtoState(jnp.ones((3, 4, 3)), jnp.ones((3, 4)))
    st1, h1 = rs1(state_s, init1(4, 3), stats)
    st2 = rs_sync(state_s, stats)
    np.testing.assert_array_equal(np.asarray(st1.proto.sum),
                                  np.asarray(st2.proto.sum))
    np.testing.assert_array_equal(
        np.asarray(read1(h1, jnp.zeros((), jnp.int32)).sum),
        np.asarray(st1.proto.sum))
