"""RoPE variants: norm preservation, relative-position property, M-RoPE
text-degeneracy, ChatGLM partial rotation."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.nn import rope

KEY = jax.random.PRNGKey(0)


def _x(B=2, S=8, H=2, D=16):
    return jax.random.normal(KEY, (B, S, H, D))


def test_rope_preserves_norm():
    x = _x()
    pos = rope.default_positions(2, 8, "rope")
    y = rope.apply_rope(x, pos, theta=1e4, kind="rope")
    np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                               jnp.linalg.norm(x, axis=-1), rtol=1e-5)


def test_rope_zero_position_is_identity():
    x = _x()
    pos = jnp.zeros((2, 8), jnp.int32)
    y = rope.apply_rope(x, pos, theta=1e4, kind="rope")
    np.testing.assert_allclose(y, x, atol=1e-6)


def test_rope_relative_property():
    """<R(p)q, R(p+k)v> depends only on k (per head)."""
    q = jax.random.normal(KEY, (1, 1, 1, 32))
    v = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 32))
    def dot_at(p, k):
        qp = rope.apply_rope(q, jnp.array([[p]]), theta=1e4, kind="rope")
        vp = rope.apply_rope(v, jnp.array([[p + k]]), theta=1e4, kind="rope")
        return float(jnp.sum(qp * vp))
    np.testing.assert_allclose(dot_at(0, 5), dot_at(17, 5), rtol=1e-4)
    np.testing.assert_allclose(dot_at(3, 11), dot_at(40, 11), rtol=1e-4)


def test_mrope_equals_rope_for_text():
    """Text tokens have t == h == w -> M-RoPE must coincide with RoPE."""
    x = _x()
    p1 = rope.default_positions(2, 8, "rope", offset=3)
    p3 = rope.default_positions(2, 8, "mrope", offset=3)
    y1 = rope.apply_rope(x, p1, theta=1e4, kind="rope")
    y3 = rope.apply_rope(x, p3, theta=1e4, kind="mrope")
    np.testing.assert_allclose(y1, y3, atol=1e-5)


def test_mrope_sections_use_different_components():
    x = jnp.ones((1, 1, 1, 32))
    p_a = jnp.array([[[5, 0, 0]]], jnp.int32)   # only t differs
    p_b = jnp.array([[[0, 0, 5]]], jnp.int32)   # only w differs
    ya = rope.apply_rope(x, p_a, theta=1e4, kind="mrope")
    yb = rope.apply_rope(x, p_b, theta=1e4, kind="mrope")
    assert not np.allclose(ya, yb)


def test_rope2d_rotates_only_half():
    x = _x(D=16)
    pos = rope.default_positions(2, 8, "rope2d", offset=1)
    y = rope.apply_rope(x, pos, theta=1e4, kind="rope2d")
    # pass-through half untouched (ChatGLM partial rotary)
    np.testing.assert_allclose(y[..., 8:], x[..., 8:], atol=1e-7)
    assert not np.allclose(y[..., :8], x[..., :8])
