"""Hypothesis property tests for system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed (see "
                    "requirements-dev.txt); property tests skipped")
from hypothesis import given, settings, strategies as st

from repro import relay as relay_lib
from repro.core import comm, losses, prototypes
from repro.launch import roofline
from repro.optim import cosine_schedule

SET = dict(max_examples=25, deadline=None)


@given(n1=st.integers(1, 30), n2=st.integers(1, 30), C=st.integers(2, 8),
       seed=st.integers(0, 2**31 - 1))
@settings(**SET)
def test_proto_merge_associative_commutative(n1, n2, C, seed):
    k = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(k, 3)
    mk = lambda kk, n: prototypes.accumulate(
        prototypes.init_state(C, 4),
        jax.random.normal(kk, (n, 4)),
        jax.random.randint(kk, (n,), 0, C))
    a, b = mk(k1, n1), mk(k2, n2)
    ab = prototypes.merge(a, b)
    ba = prototypes.merge(b, a)
    np.testing.assert_allclose(ab.sum, ba.sum, atol=1e-5)
    c = mk(k3, 5)
    left = prototypes.merge(prototypes.merge(a, b), c)
    right = prototypes.merge(a, prototypes.merge(b, c))
    np.testing.assert_allclose(left.sum, right.sum, atol=1e-5)


@given(B=st.integers(1, 8), C=st.integers(2, 12),
       seed=st.integers(0, 2**31 - 1), scale=st.floats(0.1, 5.0))
@settings(**SET)
def test_disc_loss_nonnegative_and_finite(B, C, seed, scale):
    k = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(k, 3)
    feats = jax.random.normal(k1, (B, 6)) * scale
    obs = jax.random.normal(k2, (C, 6)) * scale
    y = jax.random.randint(k3, (B,), 0, C)
    w = jax.random.normal(jax.random.PRNGKey(seed ^ 7), (6, C))
    l = float(losses.disc_loss(feats, obs, y, w))
    assert np.isfinite(l) and l >= 0.0


@given(B=st.integers(1, 6), C=st.integers(2, 10),
       seed=st.integers(0, 2**31 - 1))
@settings(**SET)
def test_mi_bound_never_exceeds_logK(B, C, seed):
    """Theorem 1 sanity: log K - L_disc <= log K (L_disc >= 0)."""
    k = jax.random.PRNGKey(seed)
    feats = jax.random.normal(k, (B, 4))
    obs = jax.random.normal(jax.random.PRNGKey(seed ^ 3), (C, 4))
    y = jax.random.randint(k, (B,), 0, C)
    w = jax.random.normal(jax.random.PRNGKey(seed ^ 5), (4, C))
    l = losses.disc_loss(feats, obs, y, w)
    assert float(losses.mi_lower_bound(l, C - 1)) <= np.log(C - 1) + 1e-6


@given(C=st.integers(2, 100), d=st.integers(1, 512),
       m_up=st.integers(1, 4), m_down=st.integers(1, 4),
       N=st.integers(2, 50))
@settings(**SET)
def test_comm_cors_linear_in_C_d(C, d, m_up, m_down, N):
    up, down = comm.cors_round_floats(C, d, m_up, m_down, N)
    assert up == N * (m_up + 1) * C * d
    assert down == N * (m_down + 1) * C * d
    up2, _ = comm.cors_round_floats(2 * C, d, m_up, m_down, N)
    assert up2 == 2 * up


@given(model_size=st.integers(10**4, 10**10), N=st.integers(2, 20),
       C=st.integers(2, 1000), d=st.integers(8, 4096))
@settings(**SET)
def test_cors_beats_fedavg_when_model_large(model_size, N, C, d):
    """Paper §Communication: CoRS volume independent of D."""
    cors_up, _ = comm.cors_round_floats(C, d, 1, 1, N)
    fl_up, _ = comm.fedavg_round_floats(model_size, N)
    if model_size > 2 * C * d:
        assert cors_up < fl_up


@given(step=st.integers(0, 10_000))
@settings(**SET)
def test_cosine_schedule_bounds(step):
    lr = float(cosine_schedule(jnp.asarray(step), base_lr=1e-3, warmup=100,
                               total=10_000))
    assert 0.0 <= lr <= 1e-3 + 1e-9


@given(a=st.floats(0.1, 10), b=st.floats(0.1, 10), c=st.floats(0.1, 10),
       n1=st.integers(1, 50), n2=st.integers(1, 50))
@settings(**SET)
def test_roofline_linear_solver_recovers_exact(a, b, c, n1, n2):
    names = ["x", "y"]
    counts = [{"x": 1, "y": 1}, {"x": 2, "y": 1}, {"x": 1, "y": 2}]
    vals = [a + b * ct["x"] + c * ct["y"] for ct in counts]
    coefs = roofline.solve_linear(counts, names, vals)
    got = roofline.evaluate_linear(coefs, {"x": n1, "y": n2})
    np.testing.assert_allclose(got, a + b * n1 + c * n2, rtol=1e-6)


@given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 40),
       C=st.integers(2, 6))
@settings(**SET)
def test_observation_within_feature_hull(seed, n, C):
    """Observations are averages -> bounded by per-dim min/max of features."""
    k = jax.random.PRNGKey(seed)
    f = jax.random.normal(k, (n, 3))
    y = jax.random.randint(jax.random.PRNGKey(seed ^ 1), (n,), 0, C)
    obs, valid = prototypes.observations(k, f, y, C, n_avg=3)
    lo, hi = f.min(axis=0), f.max(axis=0)
    v = np.asarray(valid)
    o = np.asarray(obs[0])[v]
    assert (o >= np.asarray(lo)[None] - 1e-5).all()
    assert (o <= np.asarray(hi)[None] + 1e-5).all()


@given(cap=st.integers(2, 8), k=st.integers(1, 8), C=st.integers(2, 5),
       seed=st.integers(0, 2**31 - 1))
@settings(**SET)
def test_per_class_ring_wraparound(cap, k, C, seed):
    """Appending k rows to a per-class ring: each class's pointer advances
    by its own (masked) write count mod cap_c and every masked-in write
    lands in consecutive ring slots — for any valid/mask pattern. (Writes
    per class are capped at cap_c per append, so slots are distinct.)"""
    from repro.types import CollabConfig
    k = min(k, cap)                               # per-append contract
    rng = np.random.default_rng(seed)
    ccfg = CollabConfig(num_classes=C, d_feature=2, m_down=1)
    pol = relay_lib.PerClassRelay()
    state = pol.init_state(ccfg, 2, seed=0, capacity=cap)
    ptr0 = np.asarray(state.ptr).copy()
    valid_rows = rng.random((k, C)) < 0.7
    row_mask = rng.random((k,)) < 0.7
    obs_rows = jnp.arange(1, k + 1, dtype=jnp.float32)[:, None, None] \
        * jnp.ones((k, C, 2))
    state = pol.append(state, obs_rows, jnp.asarray(valid_rows),
                       jnp.arange(k, dtype=jnp.int32),
                       row_mask=jnp.asarray(row_mask))
    w = valid_rows & row_mask[:, None]            # (k, C) actual writes
    np.testing.assert_array_equal(
        np.asarray(state.ptr), (ptr0 + w.sum(axis=0)) % cap)
    obs = np.asarray(state.obs)
    age = np.asarray(state.age)
    valid = np.asarray(state.valid)
    for c in range(C):
        for j, r in enumerate(np.nonzero(w[:, c])[0]):
            slot = (ptr0[c] + j) % cap            # j-th write of class c
            np.testing.assert_allclose(obs[c, slot], float(r + 1))
            assert age[c, slot] == 0
            assert bool(valid[c, slot])


@given(n=st.integers(1, 6), d_max=st.integers(1, 5), rounds=st.integers(2, 12),
       seed=st.integers(0, 2**31 - 1))
@settings(**SET)
def test_event_log_commit_order_monotone_and_conserving(n, d_max, rounds,
                                                        seed):
    """Event-log invariants (relay/events.py): within every round's commit
    set, birth rounds are nondecreasing (event order) with ties broken by
    upload position; every upload commits exactly once, within d_max
    rounds of its birth; and the host mirror drains completely."""
    rng = np.random.default_rng(seed)
    mirror = relay_lib.events.CommitMirror()
    order = list(rng.permutation(n))             # arbitrary upload order
    born, committed = 0, 0
    for t in range(rounds + d_max):
        active = t < rounds
        mask = rng.random(n) < 0.6 if active else np.zeros(n, bool)
        delays = rng.integers(0, d_max + 1, n)
        born += int(mask.sum()) if active else 0
        commits = mirror.step(t, mask, delays, order)
        births = [b for b, _ in commits]
        assert births == sorted(births)          # event order
        pos = {c: i for i, c in enumerate(order)}
        for (b1, c1), (b2, c2) in zip(commits, commits[1:]):
            if b1 == b2:
                assert pos[c1] < pos[c2]         # tie-break: upload pos
        for b, _ in commits:
            assert t - d_max <= b <= t           # bounded delay
        committed += len(commits)
    assert committed == born                     # exactly-once, drained


@given(d_max=st.integers(1, 4), rounds=st.integers(1, 14),
       seed=st.integers(0, 2**31 - 1))
@settings(**SET)
def test_pending_buffer_wraparound_at_dmax(d_max, rounds, seed):
    """Pending-slot reuse is collision-free: slot j = birth mod D_max is
    guaranteed free when round birth+D_max parks into it again, because the
    previous occupant committed at most D_max rounds after ITS birth. Drive
    the real array machinery (commit_and_park) with random masks/delays and
    check no live entry is ever overwritten and the buffer drains."""
    from repro.types import CollabConfig
    rng = np.random.default_rng(seed)
    N, C, d = 3, 2, 2
    ccfg = CollabConfig(num_classes=C, d_feature=d, m_up=1, m_down=1)
    pol = relay_lib.FlatRelay()
    rstate = pol.init_state(ccfg, d, seed=0, capacity=8 * N)
    pending = relay_lib.events.init_pending(N, d_max, 1, C, d)
    owner = jnp.arange(N, dtype=jnp.int32)
    for t in range(rounds + d_max):
        active = t < rounds
        mask = rng.random(N) < 0.7 if active else np.zeros(N, bool)
        delays = rng.integers(0, d_max + 1, N)
        live_before = np.asarray(pending.live)
        commit_b = np.asarray(pending.commit)
        # invariant: the slot about to be reused holds no entry that is
        # still in flight BEYOND this round
        slot = t % d_max
        assert not (live_before[:, slot] & (commit_b[:, slot] > t)).any()
        fresh = {"obs": jnp.asarray(rng.normal(size=(N, 1, C, d)),
                                    jnp.float32),
                 "valid": jnp.ones((N, C), bool),
                 "psum": jnp.zeros((N, C, d)), "pcnt": jnp.ones((N, C)),
                 "owner": owner}
        rstate, pending = relay_lib.events.commit_and_park(
            pol, rstate, pending, fresh, jnp.asarray(t, jnp.int32),
            jnp.asarray(delays, jnp.int32), jnp.asarray(mask))
        live = np.asarray(pending.live)
        commit_a = np.asarray(pending.commit)
        assert (commit_a[live] > t).all()        # live entries are future
        assert (commit_a[live] <= t + d_max).all()
    assert not np.asarray(pending.live).any()    # drained after the tail


@given(h_max=st.integers(1, 5), pushes=st.integers(0, 12),
       d=st.integers(0, 8))
@settings(**SET)
def test_history_ring_wraparound_at_hmax(h_max, pushes, d):
    """History-ring invariants (relay/history.py): `read_at(d)` returns
    EXACTLY the snapshot d pushes ago for d <= H_max−1 — never a younger
    one — and clamps deeper requests to the oldest retained snapshot
    (never older than H_max−1). Slots the run has not reached yet resolve
    to the init snapshot, the state a never-synced client would hold."""
    from repro.relay import history
    snap = lambda v: {"v": jnp.full((2,), v, jnp.float32)}
    hist = history.init(snap(0.0), h_max)
    for t in range(1, pushes + 1):
        hist = history.push(hist, snap(float(t)))
    dd = min(d, h_max - 1)                        # the documented clamp
    expect = max(pushes - dd, 0)                  # 0 = the init snapshot
    got = np.asarray(history.read_at(hist, jnp.asarray(d))["v"])
    np.testing.assert_array_equal(got, float(expect))
    assert hist.h_max == h_max                    # ring never grows


@given(rounds=st.integers(1, 8), N=st.integers(1, 6),
       seed=st.integers(0, 2**31 - 1))
@settings(**SET)
def test_downlink_billing_conserved_under_delay_maps(rounds, N, seed):
    """Downlink is billed at READ (core/comm.py): a present client fetches
    one snapshot per round no matter how stale it is, so for the same
    participation masks ANY two download-delay maps produce bit-identical
    per-round ledgers, and total downlink floats equal
    Σ_t |present_t| · (M_↓+1)·C·d'. Pins the billing point against a
    regression toward billing at snapshot age."""
    rng = np.random.default_rng(seed)
    masks = rng.random((rounds, N)) < 0.6
    C, d, m_up, m_down = 5, 3, 1, 2
    per_down = (m_down + 1) * C * d
    ledgers = []
    for _ in range(2):                  # two arbitrary delay maps
        _delays = rng.integers(0, 4, (rounds, N))   # never enters billing
        led = comm.CommLedger()
        for t in range(rounds):
            n_present = int(masks[t].sum())
            up, down = comm.round_floats(
                "cors", n_present=n_present, C=C, d=d, m_up=m_up,
                m_down=m_down, n_read=n_present)
            led.log_round(up, down)
        assert led.down_floats == per_down * int(masks.sum())
        ledgers.append(led)
    assert ledgers[0].by_round == ledgers[1].by_round


@given(cap=st.integers(1, 32), lam=st.floats(0.0, 4.0),
       seed=st.integers(0, 2**31 - 1))
@settings(**SET)
def test_staleness_weights_normalize(cap, lam, seed):
    """The staleness sampling distribution is a proper distribution: sums
    to 1 over any non-empty pool, puts zero mass outside it, and never
    weights an older slot above a fresher one."""
    rng = np.random.default_rng(seed)
    age = jnp.asarray(rng.integers(0, 100, cap), jnp.int32)
    pool = rng.random(cap) < 0.6
    if not pool.any():
        pool[rng.integers(0, cap)] = True
    w = np.asarray(relay_lib.staleness_weights(age, jnp.asarray(pool), lam))
    np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-5)
    assert (w[~pool] == 0.0).all()
    ages = np.asarray(age)
    inpool = np.nonzero(pool)[0]
    for i in inpool:
        for j in inpool:
            if ages[i] < ages[j]:
                assert w[i] >= w[j] - 1e-7


@given(seed=st.integers(0, 2**31 - 1), seats=st.integers(1, 6),
       k=st.integers(1, 4), rate=st.floats(0.0, 4.0),
       p_leave=st.floats(0.0, 1.0), population=st.integers(1, 60),
       rounds=st.integers(1, 15))
@settings(**SET)
def test_cohort_table_invariants(seed, seats, k, rate, p_leave, population,
                                 rounds):
    """Seat-table invariants (sim/population.py) for ANY spec: only active
    seats participate (k-of-active), every seated id holds exactly one
    seat and lies in the id space, a free seat is never active, and an
    evicted owner was previously seated. (An evicted id may legally be
    re-seated within the SAME round — a small id space can re-draw it as
    a fresh arrival — so "gone from the table" is not invariant.)"""
    from repro.sim import population as pop_lib
    pop = pop_lib.StreamingPopulation(k=k, rate=rate, p_leave=p_leave,
                                      population=population, seed=seed)
    t = pop.table(seats)
    seated_ever = set()
    for r in range(rounds):
        v = t.round(r)
        assert not (v.mask & ~v.active).any()
        assert int(v.mask.sum()) == min(k, int(v.active.sum()))
        assert not v.active[v.seat_ids == pop_lib.FREE_SEAT].any()
        occ = v.seat_ids[v.seat_ids != pop_lib.FREE_SEAT]
        assert len(set(occ.tolist())) == occ.size
        assert ((occ >= 0) & (occ < population)).all()
        ev = v.evicted.tolist()
        assert len(set(ev)) == len(ev)            # each owner evicted once
        for e in ev:
            assert e in seated_ever
        seated_ever.update(occ.tolist())


@given(seed=st.integers(0, 2**31 - 1), seats=st.integers(1, 5),
       k=st.integers(1, 4), rate=st.floats(0.0, 5.0),
       population=st.integers(1, 60), rounds=st.integers(1, 15))
@settings(**SET)
def test_lru_never_evicts_an_active_owner(seed, seats, k, rate, population,
                                          rounds):
    """Eviction targets only DEPARTED seats: with p_leave=0 nobody ever
    departs, so however hard arrivals press on a full table, no owner is
    ever evicted — excess arrivals are dropped (admission control)."""
    from repro.sim import population as pop_lib
    pop = pop_lib.StreamingPopulation(k=k, rate=rate, p_leave=0.0,
                                      population=population, seed=seed)
    t = pop.table(seats)
    for r in range(rounds):
        assert t.round(r).evicted.size == 0
    assert t.dropped >= 0


@given(policy=st.sampled_from(["flat", "per_class", "staleness"]),
       cap=st.integers(2, 8), k=st.integers(1, 6), n_ids=st.integers(1, 5),
       seed=st.integers(0, 2**31 - 1))
@settings(**SET)
def test_evict_owners_conserves_other_slots(policy, cap, k, n_ids, seed):
    """Slot conservation under churn, for every ring layout: eviction
    frees EXACTLY the victims' slots (owner -> EMPTY, valid cleared) and
    leaves every other slot, the write pointers and the seed slots
    bit-untouched — billing-neutral bookkeeping."""
    from repro.types import CollabConfig
    k = min(k, cap)                               # per-append contract
    rng = np.random.default_rng(seed)
    ccfg = CollabConfig(num_classes=3, d_feature=2, m_down=1)
    pol = relay_lib.get_policy(policy)
    state = pol.init_state(ccfg, 2, seed=0, capacity=cap)
    owners = rng.integers(0, n_ids, k).astype(np.int32)
    state = pol.append(state,
                       jnp.asarray(rng.normal(size=(k, 3, 2)), jnp.float32),
                       jnp.ones((k, 3), bool), jnp.asarray(owners))
    victims = np.unique(
        rng.integers(0, n_ids, max(1, n_ids // 2)).astype(np.int32))
    st2 = pol.evict_owners(state, jnp.asarray(victims))
    o1, o2 = np.asarray(state.owner), np.asarray(st2.owner)
    hit = np.isin(o1, victims)
    assert (o2[hit] == relay_lib.EMPTY_OWNER).all()
    np.testing.assert_array_equal(o2[~hit], o1[~hit])
    v1, v2 = np.asarray(state.valid), np.asarray(st2.valid)
    vhit = (hit if v1.shape == o1.shape
            else np.broadcast_to(hit[:, None], v1.shape))
    assert not v2[vhit].any()
    np.testing.assert_array_equal(v2[~vhit], v1[~vhit])
    np.testing.assert_array_equal(np.asarray(state.ptr), np.asarray(st2.ptr))
    assert (o2 == relay_lib.SEED_OWNER).sum() == \
        (o1 == relay_lib.SEED_OWNER).sum()


@given(ids=st.lists(st.integers(0, 2**31 - 1), min_size=1, max_size=32),
       S=st.integers(1, 16))
@settings(**SET)
def test_shard_hash_stable_in_range_and_elementwise(ids, S):
    """shard_of is pure, in-range and elementwise — a client's shard never
    changes and never depends on its neighbours in the batch, which is
    what lets seat churn reroute nobody."""
    from repro.relay import shards
    batch = jnp.asarray(ids, jnp.int32)
    a = np.asarray(shards.shard_of(batch, S))
    assert ((0 <= a) & (a < S)).all()
    np.testing.assert_array_equal(a, np.asarray(shards.shard_of(batch, S)))
    one = np.asarray(
        [int(shards.shard_of(jnp.asarray(i, jnp.int32), S)) for i in ids])
    np.testing.assert_array_equal(a, one)
