"""Shared pytest configuration.

- Keeps this directory on sys.path (pytest rootdir insertion), so suites
  import the consolidated oracle helpers as `from oracles import ...`.
- Pins a fixed hypothesis profile: DERANDOMIZED (examples derive from the
  test body, not a per-run RNG seed) with a bounded example budget, so
  tier-1 and the CI matrix are deterministic and fast. Individual tests
  may still override budget/deadline via @settings; derandomization stays.
  Override the budget with HYPOTHESIS_MAX_EXAMPLES for a deeper local run.
- The `slow` marker (registered in pyproject.toml, deselected by default
  via addopts) holds the heavy cross-product matrices — run them with
  `-m slow` (the separate non-blocking CI job does).
"""
import os

try:
    from hypothesis import settings

    settings.register_profile(
        "repro-ci", derandomize=True,
        max_examples=int(os.environ.get("HYPOTHESIS_MAX_EXAMPLES", "25")),
        deadline=None)
    settings.load_profile("repro-ci")
except ImportError:        # hypothesis is dev-only; property tests skip
    pass
