"""Population-scale relay: cohort shards + streaming arrivals.

Tentpole invariants (src/repro/relay/shards.py, src/repro/sim/population.py):

  - seq/vec equivalence holds across the sharded policy matrix
    S ∈ {1, 4} × {flat, per_class, staleness} — the sequential oracle
    stays the bit-exact ring-bookkeeping reference with shards on;
  - S=1 sharding is BIT-identical to the unsharded policy (the
    compatibility anchor: reduce_uploads' S=1 special case and the
    single-shard gossip mean reproduce the plain engines op-for-op);
  - streaming arrivals (unbounded external ids, bounded seat table, LRU
    owner eviction) evolve identically through both engines, with real
    evictions and admission drops exercised;
  - a shard whose cohort went quiet is a relay no-op (frozen leaves, no
    clock tick) and cross-shard gossip never divides 0/0;
  - eviction invalidates exactly the evicted owners' slots in every
    policy layout, leaving ptr/clock/billing untouched.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from oracles import run_matched
from repro import relay as relay_lib
from repro.core import client as client_lib, collab, vec_collab
from repro.data import partition, synthetic
from repro.models import mlp
from repro.obs import metrics as obs_metrics
from repro.relay import shards
from repro.sim import population
from repro.types import CollabConfig, FleetConfig, TrainConfig

SPEC = client_lib.ClientSpec(
    apply=mlp.apply,
    head=lambda p: (p["head_w"], p["head_b"]))

INNERS = ["flat", "per_class", "staleness"]


def _build(engine, fleet, mode="cors", n_clients=4, n=256, seed=0):
    # n must divide n_clients: the vectorized engine trims every client's
    # data to the shortest partition, so unequal splits break bit-parity.
    x, y = synthetic.class_images(n, seed=0, noise=0.4)
    tx, ty = synthetic.class_images(128, seed=9, noise=0.4)
    parts = partition.uniform_split(x, y, n_clients, seed=1)
    ccfg = CollabConfig(mode=mode, num_classes=10, d_feature=84,
                        lambda_kd=2.0,
                        lambda_disc=1.0 if mode == "cors" else 0.0)
    tcfg = TrainConfig(batch_size=16)
    params = [mlp.init_mlp(k)
              for k in jax.random.split(jax.random.PRNGKey(seed), n_clients)]
    cls = (collab.CollabTrainer if engine == "seq"
           else vec_collab.VectorizedCollabTrainer)
    return cls([SPEC] * n_clients, params, parts, (tx, ty), ccfg, tcfg,
               seed=seed, fleet=fleet)


# ---------------------------------------------------------------------------
# tentpole: seq/vec equivalence across the sharded policy matrix
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("inner", INNERS)
@pytest.mark.parametrize("S", [1, 4])
def test_sharded_seq_vec_equivalence(inner, S):
    fleet = FleetConfig(policy=f"sharded:{inner},{S}",
                        participation="uniform_k:2")
    run_matched(_build("seq", fleet), _build("vec", fleet), rounds=2)


def test_sharded_fd_mode_logit_reduction():
    """FD mode routes logit protos through reduce_uploads too."""
    fleet = FleetConfig(policy="sharded:flat,2", participation="uniform_k:2")
    run_matched(_build("seq", fleet, mode="fd"),
                _build("vec", fleet, mode="fd"), rounds=2)


@pytest.mark.parametrize("inner", INNERS)
def test_single_shard_is_bit_identical_to_plain(inner):
    """sharded:<inner>,1 must evolve BYTE-identical state to <inner>: the
    S=1 reduce_uploads special case mirrors the engines' builtin sum and
    the single-shard gossip mean IS the inner merge."""
    fl = lambda p: FleetConfig(policy=p, participation="uniform_k:2")
    plain = _build("vec", fl(inner))
    one = _build("vec", fl(f"sharded:{inner},1"))
    for _ in range(3):
        plain.run_round()
        one.run_round()
    ps, ss = plain.relay_state, one.relay_state
    fields = ["obs", "valid", "owner", "ptr", "global_protos", "valid_g",
              "mean_logits", "stamp", "clock"]
    if hasattr(ps, "age"):
        fields.append("age")
    for f in fields:
        np.testing.assert_array_equal(np.asarray(getattr(ps, f)),
                                      np.asarray(getattr(ss, f))[0],
                                      err_msg=f)


# ---------------------------------------------------------------------------
# tentpole: streaming arrivals through both engines
# ---------------------------------------------------------------------------
def test_streaming_seq_vec_equivalence_with_evictions():
    """Small population over few seats: departures, LRU evictions and
    admission drops all occur, and the engines agree every round (exact
    ring bookkeeping, commit lists, ledgers)."""
    fleet = FleetConfig(policy="sharded:flat,2",
                        arrivals="stream:2,1.5,0.3,7,0")
    seq = _build("seq", fleet, n_clients=3, n=192)
    vec = _build("vec", fleet, n_clients=3, n=192)
    run_matched(seq, vec, rounds=8)
    evictions = sum(seq._cohort.round(r).evicted.size for r in range(8))
    assert evictions > 0, "spec no longer exercises LRU eviction"
    assert seq._cohort.dropped > 0, "spec no longer exercises admission drop"
    # billing conservation: every round bills exactly the cohort's
    # participants — seat churn never double-bills or leaks
    ccfg = seq.ccfg
    per_client = (ccfg.m_up + 1) * ccfg.num_classes * ccfg.d_feature
    for r, rec in enumerate(seq.history):
        assert rec["comm_up"] == per_client * int(
            seq._cohort.round(r).mask.sum())


def test_streaming_unsharded_policy():
    """Arrivals do not require shards: a plain policy evicts correctly."""
    fleet = FleetConfig(policy="staleness", arrivals="stream:2,2.0,0.4,5,1")
    seq = _build("seq", fleet, n_clients=3, n=192)
    vec = _build("vec", fleet, n_clients=3, n=192)
    run_matched(seq, vec, rounds=6)


def test_streaming_round_step_compiles_once():
    """Seat churn must not retrace: external ids are a traced argument."""
    fleet = FleetConfig(policy="sharded:flat,2",
                        arrivals="stream:2,1.5,0.3,7,0")
    vec = _build("vec", fleet, n_clients=3, n=192)
    for _ in range(6):
        vec.run_round()
    assert vec._round_step._cache_size() == 1


def test_streaming_empty_cohort_rounds_are_relay_noops():
    """rate=0: nobody ever arrives, every round has zero participants, and
    the relay state stays untouched in both engines."""
    fleet = FleetConfig(policy="sharded:flat,2", arrivals="stream:2,0,0.5")
    for engine in ("seq", "vec"):
        tr = _build(engine, fleet, n_clients=2, n=128)
        state0 = jax.tree.map(
            np.asarray, tr.server.state if engine == "seq"
            else tr.relay_state)
        for _ in range(2):
            rec = tr.run_round()
            assert rec["participants"] == []
            assert rec["comm_up"] == rec["comm_down"] == 0.0
        state1 = (tr.server.state if engine == "seq" else tr.relay_state)
        jax.tree.map(np.testing.assert_array_equal, state0,
                     jax.tree.map(np.asarray, state1))


def test_streaming_composition_guards():
    """Unsupported compositions are rejected at construction, in BOTH
    engines, with the same reasons (re-filed as ROADMAP follow-ons)."""
    bad = [
        dict(policy="flat", arrivals="stream:2", participation="uniform_k:2"),
        dict(policy="flat", arrivals="stream:2", clock="lognormal:2"),
        dict(policy="flat", arrivals="stream:2", download_clock="lognormal:1"),
    ]
    for engine in ("seq", "vec"):
        for kw in bad:
            with pytest.raises(ValueError):
                _build(engine, FleetConfig(**kw), n_clients=2, n=128)
        with pytest.raises(ValueError):
            _build(engine, FleetConfig(policy="flat", arrivals="stream:2"),
                   mode="il", n_clients=2, n=128)


# ---------------------------------------------------------------------------
# sharded-policy unit mechanics
# ---------------------------------------------------------------------------
def _ccfg(C=4, d=3):
    return CollabConfig(num_classes=C, d_feature=d, m_down=1)


def _mk(S, inner=None, **kw):
    pol = shards.ShardedRelay(inner=inner or relay_lib.FlatRelay(),
                              shards=S, **kw)
    return pol, pol.init_state(_ccfg(), 3, seed=0, capacity=5)


def _ids_on_distinct_shards(S, want=2):
    """First `want` client ids that land on pairwise-distinct shards."""
    out, seen = [], set()
    for i in range(1000):
        s = int(shards.shard_of(i, S))
        if s not in seen:
            seen.add(s)
            out.append(i)
        if len(out) == want:
            return out
    raise AssertionError("hash did not cover the shards")


def test_quiet_shards_are_frozen_and_gossip_is_nan_free():
    """One committing cohort: only its shard merges/ticks; the cross-shard
    gossip mean stays finite although 3 of 4 shards contributed nothing."""
    pol, st = _mk(4)
    C, d = 4, 3
    owner = _ids_on_distinct_shards(4, want=1)[0]
    s0 = int(shards.shard_of(owner, 4))
    proto = pol.reduce_uploads(jnp.ones((1, C, d)), jnp.ones((1, C)),
                               jnp.ones((1,)), jnp.asarray([owner], jnp.int32))
    np.testing.assert_array_equal(np.asarray(proto.count).sum(axis=1) > 0,
                                  np.arange(4) == s0)
    st2 = pol.merge_round(st, proto)
    clocks = np.asarray(st2.clock)
    assert clocks[s0] == 1 and (np.delete(clocks, s0) == 0).all()
    assert np.isfinite(np.asarray(st2.global_protos)).all()
    # quiet shards are bit-frozen leaf for leaf
    for leaf0, leaf1 in zip(jax.tree.leaves(st.shards),
                            jax.tree.leaves(st2.shards)):
        for s in range(4):
            if s != s0:
                np.testing.assert_array_equal(np.asarray(leaf0)[s],
                                              np.asarray(leaf1)[s])
    assert int(st2.merges) == 1


def test_gossip_cadence_and_cross_shard_mean():
    """gossip_every=2: the first merge keeps per-shard means, the second
    replaces active shards' prototypes with the shared cross-shard mean."""
    pol, st = _mk(2, gossip_every=2)
    C, d = 4, 3
    a, b = _ids_on_distinct_shards(2)
    owners = jnp.asarray([a, b], jnp.int32)
    psum = jnp.stack([jnp.full((C, d), 2.0), jnp.full((C, d), 6.0)])
    proto = pol.reduce_uploads(psum, jnp.ones((2, C)), jnp.ones((2,)),
                               owners)
    st1 = pol.merge_round(st, proto)
    g1 = np.asarray(st1.global_protos)
    sa, sb = int(shards.shard_of(a, 2)), int(shards.shard_of(b, 2))
    np.testing.assert_allclose(g1[sa], 2.0)      # own means, no gossip yet
    np.testing.assert_allclose(g1[sb], 6.0)
    st2 = pol.merge_round(st1, proto)            # merge #2 -> gossip
    g2 = np.asarray(st2.global_protos)
    np.testing.assert_allclose(g2[sa], 4.0)      # (2 + 6) / 2
    np.testing.assert_allclose(g2[sb], 4.0)


def test_append_routes_rows_to_owner_shard_only():
    pol, st = _mk(4)
    a, b = _ids_on_distinct_shards(4)
    st2 = pol.append(st, jnp.ones((2, 4, 3)), jnp.ones((2, 4), bool),
                     jnp.asarray([a, b], jnp.int32))
    owner = np.asarray(st2.owner)                # (S, cap)
    for cid in (a, b):
        s = int(shards.shard_of(cid, 4))
        assert (owner[s] == cid).sum() == 1
        assert (np.delete(owner, s, axis=0) == cid).sum() == 0


@pytest.mark.parametrize("spec", INNERS)
def test_evict_owners_surgical_across_layouts(spec):
    """Eviction removes exactly the evicted owners' slots: other owners,
    seeds, ptr and clock are bit-untouched — in every ring layout."""
    pol = relay_lib.get_policy(spec)
    st = pol.init_state(_ccfg(), 3, seed=0, capacity=6)
    st = pol.append(st, jnp.ones((2, 4, 3)), jnp.ones((2, 4), bool),
                    jnp.asarray([5, 9], jnp.int32))
    st2 = pol.evict_owners(st, jnp.asarray([5], jnp.int32))
    o1, o2 = np.asarray(st.owner), np.asarray(st2.owner)
    v1, v2 = np.asarray(st.valid), np.asarray(st2.valid)
    hit = o1 == 5
    assert hit.any()
    assert (o2[hit] == relay_lib.EMPTY_OWNER).all()
    np.testing.assert_array_equal(o2[~hit], o1[~hit])
    # valid layout: (cap, C) for flat/staleness, owner-shaped for per_class
    vhit = (hit if v1.shape == o1.shape
            else np.broadcast_to(hit[:, None], v1.shape))
    assert not v2[vhit].any()
    np.testing.assert_array_equal(v2[~vhit], v1[~vhit])
    np.testing.assert_array_equal(np.asarray(st.ptr), np.asarray(st2.ptr))
    np.testing.assert_array_equal(np.asarray(st.clock),
                                  np.asarray(st2.clock))
    assert (o2 == 9).sum() == (o1 == 9).sum()


def test_sharded_evict_hits_every_shard():
    pol, st = _mk(2)
    a, b = _ids_on_distinct_shards(2)
    st = pol.append(st, jnp.ones((2, 4, 3)), jnp.ones((2, 4), bool),
                    jnp.asarray([a, b], jnp.int32))
    st2 = pol.evict_owners(st, jnp.asarray([a, b], jnp.int32))
    owner = np.asarray(st2.owner)
    assert (owner == a).sum() == 0 and (owner == b).sum() == 0


# ---------------------------------------------------------------------------
# spec parsing, constants, summaries
# ---------------------------------------------------------------------------
def test_sharded_policy_spec_parsing_and_validation():
    p = relay_lib.get_policy("sharded:staleness,4,2")
    assert isinstance(p, shards.ShardedRelay)
    assert isinstance(p.inner, relay_lib.StalenessRelay)
    assert p.shards == 4 and p.gossip_every == 2
    assert isinstance(relay_lib.get_policy("sharded").inner,
                      relay_lib.FlatRelay)
    with pytest.raises(ValueError):
        shards.ShardedRelay(shards=0)
    with pytest.raises(ValueError):
        shards.ShardedRelay(gossip_every=0)
    with pytest.raises(ValueError):
        shards.ShardedRelay(inner=shards.ShardedRelay())


def test_arrival_spec_parsing_and_validation():
    pop = population.get_arrivals("stream:3,1.5,0.2,1000,7")
    assert (pop.k, pop.rate, pop.p_leave, pop.population, pop.seed) == \
        (3, 1.5, 0.2, 1000, 7)
    assert population.get_arrivals(None) is None
    assert population.get_arrivals(pop) is pop
    with pytest.raises(ValueError):
        population.StreamingPopulation(k=0)
    with pytest.raises(ValueError):
        population.StreamingPopulation(p_leave=1.5)
    with pytest.raises(ValueError):
        population.StreamingPopulation(population=0)
    with pytest.raises(ValueError):
        population.get_arrivals("nope:1")


def test_free_seat_matches_empty_owner_sentinel():
    """A free seat's id must never collide with a live ring owner."""
    assert population.FREE_SEAT == relay_lib.EMPTY_OWNER


def test_relay_summary_handles_sharded_and_external_ids():
    """Telemetry reductions are shard- and id-space-generic: occupancy and
    diversity sum across shards, and external ids far beyond n_clients
    count correctly (the sweep's owner-diversity surface)."""
    pol, st = _mk(2)
    big_ids = [10_000_019, 10_000_033]           # way outside any seat range
    st = pol.append(st, jnp.ones((2, 4, 3)), jnp.ones((2, 4), bool),
                    jnp.asarray(big_ids, jnp.int32))
    occ, fill, div, hist = obs_metrics.relay_summary(st, n_clients=2)
    seeds = 2 * 1                                # one seed slot per shard
    assert int(occ) == seeds + 2
    assert int(div) == 2
    per = obs_metrics.shard_summary(st)
    assert len(per["occupancy"]) == 2
    assert sum(per["occupancy"]) == int(occ)
    assert sum(per["owner_diversity"]) == 2
    # unsharded states report as one shard
    flat_pol = relay_lib.FlatRelay()
    fst = flat_pol.init_state(_ccfg(), 3, seed=0, capacity=5)
    one = obs_metrics.shard_summary(fst)
    assert len(one["occupancy"]) == 1


def test_cohort_table_determinism_and_memory():
    pop = population.StreamingPopulation(k=2, rate=1.5, p_leave=0.3,
                                         population=50, seed=4)
    t1, t2 = pop.table(4), pop.table(4)
    for r in range(12):
        a, b = t1.round(r), t2.round(r)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)
    # replay from scratch out of order agrees too
    t3 = pop.table(4)
    v = t3.round(7)
    for x, y in zip(t1.round(7), v):
        np.testing.assert_array_equal(x, y)
    assert t1.nbytes() == t2.nbytes() > 0
