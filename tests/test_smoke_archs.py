"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned arch family runs one forward and one CoRS train step on CPU with
correct output shapes and no NaNs. Full configs are exercised only via the
dry-run (launch/dryrun.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.launch import train as train_lib
from repro.types import CollabConfig

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def _batch(cfg, key):
    batch = {"labels": jax.random.randint(key, (1, B, S), 0,
                                          cfg.vocab_size)}
    if cfg.input_kind == "tokens":
        batch["tokens"] = jax.random.randint(key, (1, B, S), 0,
                                             cfg.vocab_size)
    else:
        batch["embeddings"] = jax.random.normal(key, (1, B, S, cfg.d_model))
    if cfg.is_encoder_decoder:
        batch["tokens"] = jax.random.randint(key, (1, B, S), 0,
                                             cfg.vocab_size)
        batch["frames"] = jax.random.normal(key, (1, B, cfg.encoder_seq,
                                                  cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_reduced_forward_and_train_step(arch):
    cfg = ARCHS[arch].reduced()
    assert cfg.num_layers == 2 and cfg.d_model <= 512
    if cfg.num_experts:
        assert cfg.num_experts <= 4
    ccfg = CollabConfig(mode="cors", num_classes=cfg.vocab_size,
                        d_feature=cfg.d_feature, num_negatives=32,
                        lambda_kd=1.0, lambda_disc=0.1)
    step = train_lib.make_train_step(cfg, ccfg, n_clients=1, disc_tokens=16)
    state = train_lib.init_state(cfg, KEY, n_clients=1)
    batch = _batch(cfg, jax.random.PRNGKey(1))

    # forward shape check via the loss-internal model output
    loss_fn = train_lib.make_loss_fn(cfg, ccfg, disc_tokens=16)
    out = train_lib._lm_outputs(cfg, jax.tree.map(lambda p: p[0],
                                                  state.params),
                                jax.tree.map(lambda b: b[0], batch))
    assert out["logits"].shape == (B, S, cfg.vocab_size)
    assert out["features"].shape == (B, S, cfg.d_model)
    assert np.all(np.isfinite(np.asarray(out["logits"],
                                         dtype=np.float32)))

    new_state, metrics = jax.jit(step)(state, batch, jax.random.PRNGKey(2))
    assert np.isfinite(float(metrics["total"]))
    assert np.isfinite(float(metrics["ce"]))
    assert np.isfinite(float(metrics["kd"]))
    assert np.isfinite(float(metrics["disc"]))
    # one Adam step actually changed the params
    diff = jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        state.params, new_state.params))
    assert max(diff) > 0
    # prototype stats accumulated
    assert float(new_state.proto.count.sum()) == B * S


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_reduced_serve_decode(arch):
    from repro.launch import serve as serve_lib
    from repro.types import ShapeConfig
    cfg = ARCHS[arch].reduced()
    shape = ShapeConfig("t", seq_len=16, global_batch=2, mode="decode")
    params = (serve_lib.params_shapes(cfg), )  # shapes only (cheap check)
    # real decode
    import repro.models.encdec as encdec
    import repro.models.lm as lm
    key = jax.random.PRNGKey(0)
    if cfg.is_encoder_decoder:
        p = encdec.init_encdec(key, cfg)
        caches = {"self": encdec.init_self_cache(cfg, 2, 16),
                  "cross": (jnp.zeros((cfg.num_layers, 2, cfg.encoder_seq,
                                       cfg.num_kv_heads, cfg.head_dim)),
                            jnp.zeros((cfg.num_layers, 2, cfg.encoder_seq,
                                       cfg.num_kv_heads, cfg.head_dim)))}
        step = serve_lib.make_decode_step(cfg)
        out = jax.jit(step)(p, {"tokens": jnp.zeros((2, 1), jnp.int32)},
                            caches)
    else:
        p = lm.init_lm(key, cfg)
        caches = lm.init_cache(cfg, 2, 16)
        step = serve_lib.make_decode_step(cfg)
        if cfg.input_kind == "tokens":
            batch = {"tokens": jnp.zeros((2, 1), jnp.int32)}
        else:
            batch = {"embeddings": jnp.zeros((2, 1, cfg.d_model))}
        out = jax.jit(step)(p, batch, caches)
    assert out["logits"].shape == (2, 1, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(out["logits"], np.float32)))
