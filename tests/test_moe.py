"""MoE: routing mass, dispatch/combine correctness vs dense mixture, aux."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn import layers, moe

KEY = jax.random.PRNGKey(0)


def _params(d=16, E=4, f=8, shared=0):
    return moe.init_moe(KEY, d, E, f, shared, jnp.float32)


def test_route_mass_and_topk():
    p = _params()
    x = jax.random.normal(KEY, (2, 8, 16))
    probs, idx, aux = moe.route(p["router"], x, k=2)
    np.testing.assert_allclose(np.asarray(probs).sum(-1), 1.0, rtol=1e-5)
    assert idx.shape == (2, 8, 2)
    # aux >= 1 with equality iff perfectly balanced (Switch loss property)
    assert float(aux) >= 0.99


def _dense_moe(p, x, k, E):
    """Reference: full mixture over the top-k experts (no capacity)."""
    probs, idx, _ = moe.route(p["router"], x, k)
    def expert(e, xx):
        g = xx @ p["w_gate_e"][e]
        u = xx @ p["w_up_e"][e]
        return (jax.nn.silu(g) * u) @ p["w_down_e"][e]
    outs = jnp.stack([expert(e, x) for e in range(E)], axis=2)  # (B,S,E,d)
    onehot = jax.nn.one_hot(idx, E)                             # (B,S,k,E)
    w = jnp.einsum("bske,bsk->bse", onehot, probs)
    return jnp.einsum("bse,bsed->bsd", w, outs)


@pytest.mark.parametrize("k", [1, 2])
def test_dispatch_matches_dense_with_ample_capacity(k):
    E = 4
    p = _params(E=E)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16))
    y, aux = moe.moe_block(p, x, num_experts=E, k=k, cf=float(E),
                           num_shared=0)
    want = _dense_moe(p, x, k, E)
    np.testing.assert_allclose(y, want, atol=1e-4, rtol=1e-4)


def test_capacity_drops_overflow_tokens():
    E = 2
    p = _params(E=E)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 32, 16))
    y_small, _ = moe.moe_block(p, x, num_experts=E, k=1, cf=0.1,
                               num_shared=0)
    y_big, _ = moe.moe_block(p, x, num_experts=E, k=1, cf=4.0, num_shared=0)
    # tight capacity must change (drop) some outputs
    assert not np.allclose(y_small, y_big)
    # dropped tokens produce zeros, never NaNs
    assert np.all(np.isfinite(np.asarray(y_small)))


def test_shared_expert_added():
    p = _params(shared=1)
    x = jax.random.normal(KEY, (1, 8, 16))
    y0, _ = moe.moe_block(p, x, num_experts=4, k=2, cf=4.0, num_shared=0)
    y1, _ = moe.moe_block(p, x, num_experts=4, k=2, cf=4.0, num_shared=1)
    np.testing.assert_allclose(np.asarray(y1 - y0),
                               np.asarray(layers.swiglu(p["shared"], x)),
                               atol=1e-4)
