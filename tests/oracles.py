"""Shared seq/vec oracle assertions.

One definition of "the engines agree", imported by every equivalence suite
(test_relay_policies / test_hetero_bucketed / test_async_relay /
test_download_lag) instead of three drifting copies: ring and clock
bookkeeping must be EXACT — same pointers, owners, validity, birth stamps,
server clock and (where the policy tracks it) ages — while observations
and prototypes are float-tolerant, because the vmap-batched local updates
associate float reductions differently than the per-client oracle loop.
Ledger equality is exact: both engines bill through the same
`comm.round_floats`, so a single float of drift is a billing bug.

Telemetry (repro.obs) inherits the same split: when BOTH engines run with
telemetry on, `run_matched` additionally pins every integer leaf of each
round's `RoundTelemetry` bit-for-bit (they are reductions of the exactly-
matched ring/event bookkeeping) and holds the float leaves (drift,
per-bucket losses) to the vmap-association tolerance.
"""
import numpy as np

from repro.obs import metrics as obs_metrics

# Ring/clock fields every relay state carries and must match bit-for-bit.
EXACT_FIELDS = ("ptr", "owner", "valid", "stamp", "clock")


def assert_states_match(ss, vs, obs_atol=5e-3):
    """Exact ptr/owner/valid/stamp/clock (+age) equality; obs and
    global prototypes within `obs_atol`."""
    for f in EXACT_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(ss, f)),
                                      np.asarray(getattr(vs, f)),
                                      err_msg=f)
    if hasattr(ss, "age"):
        np.testing.assert_array_equal(np.asarray(ss.age), np.asarray(vs.age),
                                      err_msg="age")
    np.testing.assert_allclose(np.asarray(ss.obs), np.asarray(vs.obs),
                               atol=obs_atol)
    np.testing.assert_allclose(np.asarray(ss.global_protos),
                               np.asarray(vs.global_protos), atol=obs_atol)
    np.testing.assert_array_equal(np.asarray(ss.valid_g),
                                  np.asarray(vs.valid_g))


def assert_ledgers_equal(a, b):
    """Bit-exact comm-ledger agreement: per-round floats and totals."""
    assert a.by_round == b.by_round
    assert a.up_floats == b.up_floats
    assert a.down_floats == b.down_floats
    assert a.total_bytes == b.total_bytes


def assert_telemetry_match(ts, tv, float_tol=2e-2):
    """One round's telemetry records (`rec["telemetry"]` dicts) agree:
    integer leaves exactly, float leaves within `float_tol` (atol+rtol)."""
    for k in obs_metrics.EXACT_LEAVES:
        np.testing.assert_array_equal(np.asarray(ts[k]), np.asarray(tv[k]),
                                      err_msg=k)
    for k in obs_metrics.FLOAT_LEAVES:
        np.testing.assert_allclose(np.asarray(ts[k]), np.asarray(tv[k]),
                                   atol=float_tol, rtol=float_tol,
                                   err_msg=k)


def run_matched(seq, vec, rounds=3, acc_atol=2e-2):
    """Advance a sequential oracle and a vectorized engine in lockstep:
    identical participants and commit lists every round, accuracies within
    `acc_atol`, per-round telemetry agreement whenever both engines emit
    it, then exact ledger and relay-state agreement at the end."""
    for _ in range(rounds):
        rs, rv = seq.run_round(), vec.run_round()
        assert rs["participants"] == rv["participants"]
        assert rs["commits"] == rv["commits"]
        np.testing.assert_allclose(rs["accs"], rv["accs"], atol=acc_atol)
        ts, tv = rs.get("telemetry"), rv.get("telemetry")
        assert (ts is None) == (tv is None), "telemetry on in one engine"
        if ts is not None:
            assert_telemetry_match(ts, tv)
    assert_ledgers_equal(seq.ledger, vec.ledger)
    assert_states_match(seq.server.state, vec.relay_state)
