"""Attention: chunked online-softmax vs naive; windowing; GQA; decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn import attention

KEY = jax.random.PRNGKey(0)


def _qkv(B, Sq, Sk, H, G, hd, dtype=jnp.float32):
    ks = jax.random.split(KEY, 3)
    return (jax.random.normal(ks[0], (B, Sq, H, hd), dtype),
            jax.random.normal(ks[1], (B, Sk, G, hd), dtype),
            jax.random.normal(ks[2], (B, Sk, G, hd), dtype))


@pytest.mark.parametrize("H,G", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("causal", [True, False])
def test_chunked_matches_full(H, G, causal):
    q, k, v = _qkv(2, 64, 64, H, G, 16)
    got = attention.chunked_attention(q, k, v, causal=causal, chunk=16)
    want = attention.full_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_chunked_window_matches_full_window():
    q, k, v = _qkv(1, 128, 128, 4, 2, 8)
    got = attention.chunked_attention(q, k, v, causal=True, window=32,
                                      chunk=16)
    want = attention.full_attention(q, k, v, causal=True, window=32)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_window_masks_distant_tokens():
    # with window=1 every token attends only to itself -> output == v row
    q, k, v = _qkv(1, 16, 16, 2, 2, 8)
    out = attention.full_attention(q, k, v, causal=True, window=1)
    np.testing.assert_allclose(out[0, :, 0], v[0, :, 0], atol=1e-5)


def test_gqa_equals_repeated_kv():
    q, k, v = _qkv(2, 32, 32, 8, 2, 16)
    krep = jnp.repeat(k, 4, axis=2)
    vrep = jnp.repeat(v, 4, axis=2)
    got = attention.full_attention(q, k, v, causal=True)
    want = attention.full_attention(q, krep, vrep, causal=True)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_v_head_dim_differs():
    q, k, _ = _qkv(1, 16, 16, 4, 4, 8)
    v = jax.random.normal(KEY, (1, 16, 4, 12))
    out_f = attention.full_attention(q, k, v, causal=True)
    out_c = attention.chunked_attention(q, k, v, causal=True, chunk=8)
    assert out_f.shape == (1, 16, 4, 12)
    np.testing.assert_allclose(out_f, out_c, atol=2e-5, rtol=2e-5)


def test_mixed_dtype_bf16():
    q, k, v = _qkv(1, 32, 32, 4, 2, 16, jnp.bfloat16)
    got = attention.chunked_attention(q, k, v, causal=True, chunk=8)
    want = attention.full_attention(q, k, v, causal=True)
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32), atol=2e-2)


def test_gqa_decode_matches_forward_last_position():
    """Overwrite-last decode == forward with the last token replaced."""
    d, H, G, hd, S = 32, 4, 2, 8, 12
    params = attention.init_gqa(KEY, d, H, G, hd, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, S, d))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (2, S)).astype(jnp.int32)
    kw = dict(num_heads=H, num_kv_heads=G, head_dim=hd, rope_kind="rope",
              rope_theta=1e4)
    y_full, (ck, cv) = attention.gqa_block(params, x, pos, causal=True,
                                           return_kv=True, **kw)
    y_dec, _, _ = attention.gqa_decode(params, x[:, -1:], ck, cv,
                                       pos[:, -1:], **kw)
    np.testing.assert_allclose(y_dec[:, 0], y_full[:, -1], atol=1e-4,
                               rtol=1e-4)
