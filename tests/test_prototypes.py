"""Prototype statistics: accumulation, merging, observations (Alg. 1)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding
from repro.core import prototypes

KEY = jax.random.PRNGKey(0)


def test_accumulate_matches_manual():
    f = jax.random.normal(KEY, (20, 6))
    y = jax.random.randint(jax.random.PRNGKey(1), (20,), 0, 4)
    st = prototypes.accumulate(prototypes.init_state(4, 6), f, y)
    for c in range(4):
        mask = np.asarray(y) == c
        np.testing.assert_allclose(st.sum[c], np.asarray(f)[mask].sum(0),
                                   atol=1e-5)
        assert float(st.count[c]) == mask.sum()


def test_merge_equals_joint_accumulation():
    f = jax.random.normal(KEY, (30, 5))
    y = jax.random.randint(jax.random.PRNGKey(1), (30,), 0, 3)
    a = prototypes.accumulate(prototypes.init_state(3, 5), f[:15], y[:15])
    b = prototypes.accumulate(prototypes.init_state(3, 5), f[15:], y[15:])
    joint = prototypes.accumulate(prototypes.init_state(3, 5), f, y)
    m = prototypes.merge(a, b)
    np.testing.assert_allclose(m.sum, joint.sum, atol=1e-4)
    np.testing.assert_allclose(m.count, joint.count)


def test_means_fallback_for_empty_class():
    st = prototypes.init_state(3, 2)
    st = prototypes.accumulate(st, jnp.ones((2, 2)), jnp.array([0, 0]))
    fb = jnp.full((3, 2), 7.0)
    m = prototypes.means(st, fallback=fb)
    np.testing.assert_allclose(m[0], [1, 1])
    np.testing.assert_allclose(m[1], [7, 7])


def test_observations_average_n_avg_samples():
    # class 0 has exactly 3 identical samples -> observation == the sample
    f = jnp.concatenate([jnp.full((3, 4), 2.0),
                         jax.random.normal(KEY, (10, 4))])
    y = jnp.concatenate([jnp.zeros(3, jnp.int32),
                         jnp.ones(10, jnp.int32)])
    obs, valid = prototypes.observations(KEY, f, y, 3, n_avg=3, m_up=2)
    assert obs.shape == (2, 3, 4)
    np.testing.assert_allclose(obs[:, 0], 2.0, atol=1e-5)
    assert bool(valid[0]) and bool(valid[1]) and not bool(valid[2])


def test_observations_concentrate_with_n_avg():
    # variance of the observation decreases with n_avg (paper §3, Eq. 2)
    f = jax.random.normal(KEY, (400, 8))
    y = jnp.zeros((400,), jnp.int32)
    keys = jax.random.split(jax.random.PRNGKey(5), 30)
    def spread(n_avg):
        os = jnp.stack([prototypes.observations(k, f, y, 1, n_avg)[0][0, 0]
                        for k in keys])
        return float(jnp.mean(jnp.var(os, axis=0)))
    assert spread(50) < spread(2)


def test_psum_merge_single_device():
    st = prototypes.accumulate(prototypes.init_state(2, 3),
                               jnp.ones((4, 3)), jnp.zeros(4, jnp.int32))
    def f(s):
        return prototypes.psum_merge(s, "i")
    out = sharding.shard_map(f, mesh=jax.make_mesh((1,), ("i",)),
                             in_specs=jax.sharding.PartitionSpec(),
                             out_specs=jax.sharding.PartitionSpec())(st)
    np.testing.assert_allclose(out.sum, st.sum)
