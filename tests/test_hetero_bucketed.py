"""Bucketed heterogeneous engine (core/vec_collab.py) vs the sequential
oracle.

The tentpole invariant: for a MIXED-spec fleet (≥2 stackable buckets,
interleaved client ids) the bucketed vectorized engine and the sequential
oracle evolve identical relay ring bookkeeping (exact ptr/owner/valid/age)
and the same per-client eval metrics, across relay policies × participation
schedules — because both write uploads in the same bucket order
(client_lib.bucketize) under the same per-round key schedule. Plus bucket
construction mechanics and the no-retrace guarantees of the per-bucket
steps and the shared relay commit.
"""
import jax
import numpy as np
import pytest

from oracles import assert_states_match as _assert_states_match
from repro import relay as relay_lib
from repro.core import client as client_lib, collab, vec_collab
from repro.data import partition, synthetic
from repro.models import cnn, mlp
from repro.types import CollabConfig, FleetConfig, TrainConfig

# Two distinct spec OBJECTS (identical callables hash apart on purpose) +
# two MLP widths: widths alone would already split buckets by param shape,
# the distinct objects make this the documented usage.
MLP_A = client_lib.ClientSpec(
    apply=lambda p, x: mlp.apply(p, x),
    head=lambda p: (p["head_w"], p["head_b"]))
MLP_B = client_lib.ClientSpec(
    apply=lambda p, x: mlp.apply(p, x),
    head=lambda p: (p["head_w"], p["head_b"]))
CNN_SPEC = client_lib.ClientSpec(
    apply=lambda p, x: cnn.apply(p, x),
    head=lambda p: (p["head_w"], p["head_b"]))


def _fleet(n_clients=4, seed=0, with_cnn=False):
    """Interleaved mixed fleet: even ids -> MLP_A(h=64), odd -> MLP_B(h=96),
    optionally the last client a CNN (third bucket)."""
    keys = jax.random.split(jax.random.PRNGKey(seed), n_clients)
    specs, params = [], []
    for i, k in enumerate(keys):
        if with_cnn and i == n_clients - 1:
            specs.append(CNN_SPEC)
            params.append(cnn.init_cnn(k))
        elif i % 2 == 0:
            specs.append(MLP_A)
            params.append(mlp.init_mlp(k, hidden=64))
        else:
            specs.append(MLP_B)
            params.append(mlp.init_mlp(k, hidden=96))
    return specs, params


def _build(engine, policy, schedule, mode="cors", n_clients=4, n=256,
           seed=0, with_cnn=False):
    x, y = synthetic.class_images(n, seed=0, noise=0.4)
    tx, ty = synthetic.class_images(128, seed=9, noise=0.4)
    parts = partition.uniform_split(x, y, n_clients, seed=1)
    ccfg = CollabConfig(mode=mode, num_classes=10, d_feature=84,
                        lambda_kd=2.0,
                        lambda_disc=1.0 if mode == "cors" else 0.0)
    tcfg = TrainConfig(batch_size=16)
    specs, params = _fleet(n_clients, seed, with_cnn)
    cls = (collab.CollabTrainer if engine == "seq"
           else vec_collab.VectorizedCollabTrainer)
    return cls(specs, params, parts, (tx, ty), ccfg, tcfg, seed=seed,
               fleet=FleetConfig(policy=policy, participation=schedule))


# ---------------------------------------------------------------------------
# tentpole: seq/vec equivalence for mixed-spec fleets
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", ["flat", "per_class", "staleness"])
@pytest.mark.parametrize("schedule", ["full", "uniform_k:2", "bernoulli:0.5"])
def test_hetero_seq_vec_equivalence(policy, schedule):
    seq = _build("seq", policy, schedule)
    vec = _build("vec", policy, schedule)
    assert vec.hetero and len(vec.buckets) == 2
    assert [list(b.ids) for b in vec.buckets] == [[0, 2], [1, 3]]
    for _ in range(2):
        rs, rv = seq.run_round(), vec.run_round()
        assert rs["participants"] == rv["participants"]
        np.testing.assert_allclose(rs["accs"], rv["accs"], atol=2e-2)
        for a, b in zip(rs["metrics"], rv["metrics"]):
            assert sorted(a) == sorted(b)
            for k in a:
                np.testing.assert_allclose(a[k], b[k], rtol=1e-3, atol=1e-4)
    assert seq.ledger.by_round == vec.ledger.by_round
    assert seq.ledger.total_bytes == vec.ledger.total_bytes
    _assert_states_match(seq.server.state, vec.relay_state)


def test_hetero_three_buckets_fd_mode():
    """FD mode (logit prototypes) + a third CNN bucket: the cross-bucket
    proto AND logit-proto merges must both match the oracle."""
    seq = _build("seq", "flat", "full", mode="fd", with_cnn=True)
    vec = _build("vec", "flat", "full", mode="fd", with_cnn=True)
    assert len(vec.buckets) == 3
    for _ in range(2):
        rs, rv = seq.run_round(), vec.run_round()
        np.testing.assert_allclose(rs["accs"], rv["accs"], atol=2e-2)
    np.testing.assert_allclose(np.asarray(seq.server.state.mean_logits),
                               np.asarray(vec.relay_state.mean_logits),
                               atol=5e-3)
    _assert_states_match(seq.server.state, vec.relay_state)


def test_hetero_zero_participant_round_is_relay_noop():
    class NoShow(relay_lib.ParticipationSchedule):
        name = "noshow"

        def mask(self, round_idx, n_clients):
            return np.zeros((n_clients,), bool)

    vec = _build("vec", "staleness", NoShow(), n_clients=2, n=128)
    state0 = jax.tree.map(np.asarray, vec.relay_state)
    rec = vec.run_round()
    assert rec["participants"] == []
    assert rec["comm_up"] == rec["comm_down"] == 0.0
    jax.tree.map(np.testing.assert_array_equal, state0,
                 jax.tree.map(np.asarray, vec.relay_state))


# ---------------------------------------------------------------------------
# bucket construction + compile-once mechanics
# ---------------------------------------------------------------------------
def test_bucketize_groups_by_spec_and_shape():
    keys = jax.random.split(jax.random.PRNGKey(0), 4)
    # same spec object, two widths -> shape split; order = first appearance
    specs = [MLP_A, MLP_A, MLP_A, MLP_A]
    params = [mlp.init_mlp(k, hidden=64 if i in (0, 3) else 96)
              for i, k in enumerate(keys)]
    buckets = client_lib.bucketize(specs, params)
    assert [ids for _, ids in buckets] == [[0, 3], [1, 2]]
    # homogeneous fleet -> ONE bucket, identity order
    params64 = [mlp.init_mlp(k, hidden=64) for k in keys]
    buckets = client_lib.bucketize(specs, params64)
    assert [ids for _, ids in buckets] == [[0, 1, 2, 3]]


def test_hetero_upload_order_is_bucket_order():
    seq = _build("seq", "flat", "full")
    assert seq._upload_order == [0, 2, 1, 3]


def test_hetero_steps_compile_once():
    """Participation must not retrace the per-bucket steps or the shared
    relay commit: 3 rounds under a varying-k schedule = 1 trace each."""
    vec = _build("vec", "per_class", "bernoulli:0.7")
    for _ in range(3):
        vec.run_round()
    for b in vec.buckets:
        assert b.step._cache_size() == 1
    assert vec._relay_commit._cache_size() == 1


def test_hetero_client_params_roundtrip():
    vec = _build("vec", "flat", "full")
    p1 = vec.client_params(1)                     # bucket B, slot 0
    assert p1["w1"].shape[-1] == 96
    p0 = vec.client_params(0)
    assert p0["w1"].shape[-1] == 64
