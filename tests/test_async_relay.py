"""Asynchronous event-ordered relay (src/repro/relay/events.py + sim/).

The tentpole invariant: under bounded-delay uploads, the vectorized
engine's jitted pending-buffer commit and the sequential oracle's
host-side event replay evolve IDENTICAL relay state — exact ring pointers,
owners, validity, clock stamps and ages — across every relay policy ×
clock model, with identical per-round commit lists and comm ledgers. Plus:
the D_max=0 async machinery is bit-identical to the synchronous engines,
zero-commit rounds are relay no-ops, billing follows commit/sync rounds,
the async step never retraces, the adaptive schedule closes the loop
deterministically, and `make_async_round_sync` conserves prototype mass.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from oracles import run_matched as _run_matched
from repro import relay as relay_lib, sim
from repro.core import client as client_lib, collab, prototypes, vec_collab
from repro.data import partition, synthetic
from repro.launch import train
from repro.models import mlp
from repro.types import CollabConfig, FleetConfig, TrainConfig

SPEC = client_lib.ClientSpec(
    apply=lambda p, x: mlp.apply(p, x),
    head=lambda p: (p["head_w"], p["head_b"]))
SPEC_B = client_lib.ClientSpec(
    apply=lambda p, x: mlp.apply(p, x),
    head=lambda p: (p["head_w"], p["head_b"]))

POLICIES = ["flat", "per_class", "staleness"]
CLOCKS = ["homogeneous:1", "lognormal:2", "periodic:2,3"]


def _build(engine, policy, clock, schedule=None, mode="cors", n_clients=4,
           n=192, seed=0, hetero=False, mesh=None):
    x, y = synthetic.class_images(n, seed=0, noise=0.4)
    tx, ty = synthetic.class_images(96, seed=9, noise=0.4)
    parts = partition.uniform_split(x, y, n_clients, seed=1)
    ccfg = CollabConfig(mode=mode, num_classes=10, d_feature=84,
                        lambda_kd=2.0,
                        lambda_disc=1.0 if mode == "cors" else 0.0)
    tcfg = TrainConfig(batch_size=16)
    keys = jax.random.split(jax.random.PRNGKey(seed), n_clients)
    if hetero:
        specs = [SPEC if i % 2 == 0 else SPEC_B for i in range(n_clients)]
        params = [mlp.init_mlp(k, hidden=64 if i % 2 == 0 else 96)
                  for i, k in enumerate(keys)]
    else:
        specs = [SPEC] * n_clients
        params = [mlp.init_mlp(k) for k in keys]
    cls = (collab.CollabTrainer if engine == "seq"
           else vec_collab.VectorizedCollabTrainer)
    return cls(specs, params, parts, (tx, ty), ccfg, tcfg, seed=seed,
               fleet=FleetConfig(policy=policy, participation=schedule,
                                 clock=clock, mesh=mesh))


# ---------------------------------------------------------------------------
# tentpole: seq event replay == vec pending buffer, policy × clock matrix
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("clock", CLOCKS)
def test_async_seq_vec_equivalence(policy, clock):
    _run_matched(_build("seq", policy, clock), _build("vec", policy, clock))


def test_async_fd_mode_and_partial_participation():
    """Delayed logit-proto commits (FD) under a variable-count schedule."""
    _run_matched(_build("seq", "flat", "lognormal:2", "bernoulli:0.5",
                        mode="fd"),
                 _build("vec", "flat", "lognormal:2", "bernoulli:0.5",
                        mode="fd"), rounds=4)


def test_async_hetero_buckets():
    """Two interleaved buckets share ONE pending buffer (upload-position
    indexed): delayed commits must still land in bucket-event order."""
    _run_matched(_build("seq", "staleness", "periodic:2,3", hetero=True),
                 _build("vec", "staleness", "periodic:2,3", hetero=True))


def test_dmax0_machinery_bit_identical_to_sync():
    """HomogeneousClock(0, d_max=1) forces the pending-buffer machinery
    with every delay 0: both engines must match their clock=None selves
    bit-for-bit (the acceptance anchor for D_max = 0)."""
    for engine in ("seq", "vec"):
        a = _build(engine, "staleness", sim.HomogeneousClock(0, d_max=1),
                   n_clients=3)
        b = _build(engine, "staleness", None, n_clients=3)
        if engine == "vec":
            assert a._async and not b._async
        for _ in range(2):
            ra, rb = a.run_round(), b.run_round()
            assert ra["commits"] == rb["commits"]
            assert ra["accs"] == rb["accs"]
        sa = a.server.state if engine == "seq" else a.relay_state
        sb = b.server.state if engine == "seq" else b.relay_state
        jax.tree.map(lambda x, y: np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y)), sa, sb)
        assert a.ledger.by_round == b.ledger.by_round


# ---------------------------------------------------------------------------
# commit timing semantics: no-op rounds, billing, staleness pre-aging
# ---------------------------------------------------------------------------
def test_zero_commit_round_is_relay_noop_and_bills_no_uplink():
    """homogeneous:2 parks EVERY upload for 2 rounds: rounds 0-1 have no
    commits (relay untouched, zero uplink billed, downlink still billed
    for the syncing clients); round 2 commits round 0's uploads."""
    for engine in ("seq", "vec"):
        tr = _build(engine, "flat", "homogeneous:2", n_clients=3)
        state0 = jax.tree.map(
            np.asarray,
            tr.server.state if engine == "seq" else tr.relay_state)
        ccfg = tr.ccfg
        down_per = (ccfg.m_down + 1) * ccfg.num_classes * ccfg.d_feature
        up_per = (ccfg.m_up + 1) * ccfg.num_classes * ccfg.d_feature
        for r in range(2):
            rec = tr.run_round()
            assert rec["commits"] == []
            assert rec["comm_up"] == 0.0
            assert rec["comm_down"] == 3 * down_per
        state1 = jax.tree.map(
            np.asarray,
            tr.server.state if engine == "seq" else tr.relay_state)
        jax.tree.map(np.testing.assert_array_equal, state0, state1)
        rec = tr.run_round()                    # round 2: birth-0 commits
        assert rec["commits"] == [[0, 0], [0, 1], [0, 2]]
        assert rec["comm_up"] == 3 * up_per


def test_uplink_floats_conserved_after_drain():
    """Async shifts uplink billing across rounds but never loses or
    invents floats: after the queue drains, totals equal the sync run."""
    a = _build("seq", "flat", "lognormal:2", n_clients=4)
    b = _build("seq", "flat", None, n_clients=4)
    for _ in range(4):
        a.run_round()
        b.run_round()
    # drain: no new births, only pending commits
    a.schedule = relay_lib.get_schedule(_NoShow(), seed=0)
    while len(a._queue):
        a.run_round()
    assert a.ledger.up_floats == b.ledger.up_floats
    assert a.ledger.down_floats == b.ledger.down_floats


class _NoShow(relay_lib.ParticipationSchedule):
    name = "noshow"

    def mask(self, round_idx, n_clients):
        return np.zeros((n_clients,), bool)


def test_delayed_commit_arrives_preaged_under_staleness():
    """A row born at clock c committing after d merges must enter with
    age = current clock − c, not age 0: clock-based staleness sees through
    the delay."""
    ccfg = CollabConfig(num_classes=3, d_feature=2, m_down=1)
    pol = relay_lib.get_policy("staleness")
    st = pol.init_state(ccfg, 2, capacity=4)
    proto = prototypes.ProtoState(jnp.ones((3, 2)), jnp.ones((3,)))
    st = pol.merge_round(st, proto)              # clock 1
    st = pol.merge_round(st, proto)              # clock 2
    st = pol.append(st, jnp.ones((1, 3, 2)), jnp.ones((1, 3), bool),
                    jnp.asarray([7], jnp.int32),
                    stamp_rows=jnp.asarray([0], jnp.int32))  # born at 0
    assert int(np.asarray(st.age)[1]) == 2       # pre-aged on arrival
    st = pol.merge_round(st, proto)              # clock 3
    assert int(np.asarray(st.age)[1]) == 3
    assert int(np.asarray(st.stamp)[1]) == 0


# ---------------------------------------------------------------------------
# engine mechanics: no retrace, mesh composition, compaction fallback
# ---------------------------------------------------------------------------
def test_async_round_step_compiles_once():
    """round_idx and delays are traced args: 3 rounds = 1 compile."""
    vec = _build("vec", "per_class", "lognormal:2", n_clients=4)
    for _ in range(3):
        vec.run_round()
    assert vec._round_step._cache_size() == 1


def test_async_composes_with_mesh():
    """async × mesh used to raise ("pending buffer holds per-client
    in-flight rows"); under the placement API the pending buffer IS
    client-sharded (events.out_spec) and the commit payload is the round's
    one exchange — so it runs, matches the oracle exactly, and still
    compiles once."""
    from repro import sharding
    seq = _build("seq", "staleness", "lognormal:2")
    vec = _build("vec", "staleness", "lognormal:2",
                 mesh=sharding.client_mesh(1))
    _run_matched(seq, vec)
    assert vec._round_step._cache_size() == 1


def test_async_disables_static_k_compaction():
    """Lateness decouples the commit set from the participant set, so the
    async step must run full-width even under a fixed-k schedule — and
    still match the oracle exactly."""
    seq = _build("seq", "flat", "lognormal:2", schedule="uniform_k:2")
    vec = _build("vec", "flat", "lognormal:2", schedule="uniform_k:2")
    assert vec._k_active == vec.n_clients        # no participant gather
    _run_matched(seq, vec)


# ---------------------------------------------------------------------------
# clock models + adaptive participation
# ---------------------------------------------------------------------------
def test_clock_models_deterministic_and_bounded():
    for spec in ("homogeneous:1", "lognormal:3", "periodic:2,3"):
        a, b = sim.get_clock(spec, seed=4), sim.get_clock(spec, seed=4)
        for r in range(6):
            da, db = a.delays(r, 8), b.delays(r, 8)
            np.testing.assert_array_equal(da, db)
            assert (da >= 0).all() and (da <= a.d_max).all()
    assert sim.get_clock(None) is None
    assert sim.get_clock("none") is None
    assert sim.get_clock("homogeneous").d_max == 0
    with pytest.raises(ValueError):
        sim.get_clock("warp:9")


def test_periodic_clock_waits_for_next_window():
    c = sim.PeriodicClock(d_max=4, period=3)
    d0 = c.delays(0, 6)
    np.testing.assert_array_equal(d0, [0, 1, 2, 0, 1, 2])
    d1 = c.delays(1, 6)
    np.testing.assert_array_equal(d1, [2, 0, 1, 2, 0, 1])


def test_adaptive_schedule_deterministic_and_boosts_stragglers():
    clock = sim.LognormalClock(d_max=4, sigma=1.2, seed=3)
    a = relay_lib.get_schedule("adaptive:0.4,2", seed=7, clock=clock)
    b = relay_lib.get_schedule("adaptive:0.4,2", seed=7, clock=clock)
    R, N = 40, 8
    for r in range(R):
        np.testing.assert_array_equal(a.mask(r, N), b.mask(r, N))
    freq = np.mean([a.mask(r, N) for r in range(R)], axis=0)
    mean_delay = np.mean([clock.delays(r, N) for r in range(R)], axis=0)
    stragglers = mean_delay > np.median(mean_delay)
    assert freq[stragglers].mean() > freq[~stragglers].mean()
    # unbound adaptive degenerates to plain bernoulli-style base rate
    c = relay_lib.get_schedule("adaptive:0.4", seed=7)
    assert c.clock is None
    m = np.mean([c.mask(r, 64) for r in range(30)])
    assert abs(m - 0.4) < 0.1


# ---------------------------------------------------------------------------
# LM-scale async round sync (launch/train.py)
# ---------------------------------------------------------------------------
def test_async_round_sync_conserves_and_drains():
    ccfg = CollabConfig(num_classes=4, d_feature=3)
    init_p, rs_async = train.make_async_round_sync(ccfg, d_max=2)
    rs_sync = train.make_round_sync(ccfg)
    mk_state = lambda: train.TrainState(None, None,
                                        prototypes.init_state(4, 3),
                                        jnp.zeros((), jnp.int32))
    state, state_s = mk_state(), mk_state()
    pending = init_p(4, 3)
    rng = np.random.default_rng(0)
    for r in range(7):                           # 5 rounds + 2 drain
        if r < 5:
            stats = prototypes.ProtoState(
                jnp.asarray(rng.normal(size=(3, 4, 3)), jnp.float32),
                jnp.asarray(rng.random((3, 4)), jnp.float32))
            delays = jnp.asarray(rng.integers(0, 3, 3), jnp.int32)
            state_s = rs_sync(state_s, stats)
        else:
            stats = prototypes.ProtoState(jnp.zeros((3, 4, 3)),
                                          jnp.zeros((3, 4)))
            delays = jnp.zeros((3,), jnp.int32)
        state, pending = rs_async(state, pending, delays, stats)
    np.testing.assert_allclose(np.asarray(state.proto.sum),
                               np.asarray(state_s.proto.sum), atol=1e-5)
    np.testing.assert_allclose(np.asarray(state.proto.count),
                               np.asarray(state_s.proto.count), atol=1e-5)
    assert float(jnp.abs(pending.sum).max()) == 0.0   # fully drained

    # d_max=0 degenerates to make_round_sync bit-exactly
    init0, rs0 = train.make_async_round_sync(ccfg, d_max=0)
    stats = prototypes.ProtoState(jnp.ones((3, 4, 3)), jnp.ones((3, 4)))
    st0, _ = rs0(state_s, init0(4, 3), jnp.zeros((3,), jnp.int32), stats)
    st1 = rs_sync(state_s, stats)
    np.testing.assert_array_equal(np.asarray(st0.proto.sum),
                                  np.asarray(st1.proto.sum))
